"""Adaptive decode serving (repro.serve).

Tier-1 covers the deterministic logic: seeded arrival reproducibility,
continuous-batcher invariants (retire-before-admit, bounded occupancy,
FIFO no-starvation), SLO accounting exactness on hand-built traces, the
serving objective math, the fused-prefill/token-stepping equivalence at
model level, the decode-vs-prefill workload asymmetry through
``derive_stage_costs``, the stateless ``PlanRuntime`` serving mode, and —
on the seeded Fig-10 serving scenario — the acceptance observables: the
tuner's serve trail crossing schedule kinds, regime-divergent choices, and
serving trace tracks passing the existing no-overlap gate.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.devicespec import (
    derive_stage_costs,
    load_device_spec,
    load_workload_profile,
    spec_root,
)
from repro.models import api
from repro.obs import Observability
from repro.obs.trace import quantize_sim_span, spans_by_track, validate_no_overlap
from repro.serve import (
    ArrivalProcess,
    ContinuousBatcher,
    InFlight,
    Request,
    RequestQueue,
    SLOTracker,
    make_slo_objective,
)

# ---------------------------------------------------------------------------
# Arrival process
# ---------------------------------------------------------------------------


def test_arrivals_seeded_reproducible():
    a = ArrivalProcess(5.0, seed=7, burst_factor=3.0)
    b = ArrivalProcess(5.0, seed=7, burst_factor=3.0)
    ra = a.drain(20.0)
    rb = b.drain(20.0)
    assert ra == rb
    assert len(ra) > 0
    # different seed -> different stream
    rc = ArrivalProcess(5.0, seed=8, burst_factor=3.0).drain(20.0)
    assert [r.arrival_time for r in rc] != [r.arrival_time for r in ra]


def test_arrivals_poisson_rate():
    reqs = ArrivalProcess(10.0, seed=0).drain(200.0)
    # ~2000 expected; 5 sigma ~ 220
    assert 1700 <= len(reqs) <= 2300
    times = [r.arrival_time for r in reqs]
    assert times == sorted(times)
    assert all(0.0 < t <= 200.0 for t in times)


def test_arrivals_burst_factor_raises_rate():
    calm = len(ArrivalProcess(5.0, seed=3).drain(100.0))
    bursty = len(
        ArrivalProcess(
            5.0, seed=3, burst_factor=4.0, mean_calm=1.0, mean_burst=1.0
        ).drain(100.0)
    )
    # ~half the time at 4x rate -> ~2.5x the arrivals
    assert bursty > 1.5 * calm


def test_arrivals_drain_monotone_and_disjoint():
    a = ArrivalProcess(8.0, seed=1, burst_factor=2.0)
    first = a.drain(5.0)
    second = a.drain(10.0)
    assert all(r.arrival_time <= 5.0 for r in first)
    assert all(5.0 < r.arrival_time <= 10.0 for r in second)
    assert a.drain(10.0) == []  # already drained
    rids = [r.rid for r in first + second]
    assert rids == sorted(set(rids))


def test_arrivals_next_arrival_after():
    a = ArrivalProcess(2.0, seed=5)
    t = a.next_arrival_after(3.0)
    assert t is not None and t > 3.0
    assert a.drain(t) != []  # skipping to t lands on a real arrival
    assert ArrivalProcess(0.0).next_arrival_after(0.0) is None


def test_arrivals_sampled_ranges():
    reqs = ArrivalProcess(
        20.0, seed=2, prompt_len=(4, 9), new_tokens=(2, 5)
    ).drain(20.0)
    assert reqs
    assert all(4 <= r.prompt_len <= 9 for r in reqs)
    assert all(2 <= r.max_new_tokens <= 5 for r in reqs)


def test_arrivals_validation():
    with pytest.raises(ValueError):
        ArrivalProcess(-1.0)
    with pytest.raises(ValueError):
        ArrivalProcess(1.0, burst_factor=0.5)


# ---------------------------------------------------------------------------
# Queue + continuous batcher invariants
# ---------------------------------------------------------------------------


def _req(rid, t=0.0, budget=2):
    return Request(rid=rid, arrival_time=t, prompt_len=4, max_new_tokens=budget)


def test_batcher_fifo_and_occupancy_bound():
    q = RequestQueue()
    for i in range(10):
        q.push(_req(i))
    b = ContinuousBatcher(4)
    admitted = b.admit(q, now=0.0)
    assert [inf.request.rid for inf in admitted] == [0, 1, 2, 3]  # FIFO
    assert b.occupancy == 4 and len(q) == 6
    assert b.admit(q, now=1.0) == []  # full: admits nothing, raises nothing
    # finish two, retire, re-admit: strictly the next two in line
    for inf in admitted[:2]:
        inf.tokens_emitted = inf.request.max_new_tokens
    done = b.retire_finished(now=2.0)
    assert [inf.request.rid for inf in done] == [0, 1]
    again = b.admit(q, now=2.0)
    assert [inf.request.rid for inf in again] == [4, 5]
    assert b.occupancy == 4
    assert b.total_admitted == 6 and b.total_retired == 2


def test_batcher_admit_before_retire_raises():
    q = RequestQueue()
    q.push(_req(0))
    q.push(_req(1))
    b = ContinuousBatcher(1)
    (inf,) = b.admit(q, now=0.0)
    inf.tokens_emitted = inf.request.max_new_tokens
    with pytest.raises(RuntimeError, match="retire_finished"):
        b.admit(q, now=1.0)
    b.retire_finished(now=1.0)
    assert [i.request.rid for i in b.admit(q, now=1.0)] == [1]


def test_batcher_no_starvation():
    """Any queued request is admitted after at most the requests ahead of it:
    admission order equals enqueue order, regardless of retire pattern."""
    q = RequestQueue()
    rng = np.random.default_rng(0)
    for i in range(30):
        q.push(_req(i, budget=int(rng.integers(1, 4))))
    b = ContinuousBatcher(3)
    order = []
    now = 0.0
    while len(order) < 30:
        b.retire_finished(now)
        order += [inf.request.rid for inf in b.admit(q, now)]
        for inf in b.in_flight:  # one tick: everyone emits one token
            inf.tokens_emitted += 1
        now += 1.0
    assert order == list(range(30))


# ---------------------------------------------------------------------------
# SLO accounting exactness (hand-built trace)
# ---------------------------------------------------------------------------


def test_slo_tracker_exact_ttft_tpot():
    obs = Observability.create()
    slo = SLOTracker(obs.metrics, trace=obs.trace, ttft_slo=0.5, tpot_slo=0.15)
    # request arrives t=1, admitted t=2, first token t=3, tokens at 4, 5, done 5
    inf = InFlight(request=_req(0, t=1.0, budget=3), slot=0, admit_time=2.0)
    slo.on_admit(inf, 2.0)
    slo.on_first_token(inf, 3.0)
    slo.on_token(inf, 4.0)
    slo.on_token(inf, 5.0)
    slo.on_complete(inf, 5.0)
    s = slo.summary()
    assert s["completed"] == 1 and s["tokens"] == 3.0
    assert s["ttft_p50"] == pytest.approx(2.0)  # arrival 1 -> first token 3
    assert s["tpot_p50"] == pytest.approx(1.0)  # (5-3)/(3-1)
    assert s["token_latency_p50"] == pytest.approx(1.0)
    assert s["slo_attainment"] == 0.0  # both targets missed


def test_slo_tracker_attainment_mixed():
    obs = Observability.create()
    slo = SLOTracker(obs.metrics, ttft_slo=1.0, tpot_slo=1.0)
    for rid, (admit, first) in enumerate([(0.0, 0.5), (0.0, 2.0)]):
        inf = InFlight(request=_req(rid, t=0.0, budget=1), slot=0, admit_time=admit)
        slo.on_admit(inf, admit)
        slo.on_first_token(inf, first)
        slo.on_complete(inf, first)
    assert slo.attainment() == 0.5
    # budget-1 request has no TPOT sample: only the TTFT target judges it
    assert slo.summary()["tpot_p50"] == 0.0


def test_slo_tracker_quantiles_match_numpy():
    obs = Observability.create()
    slo = SLOTracker(obs.metrics)
    rng = np.random.default_rng(0)
    gaps = rng.exponential(0.05, size=500)
    inf = InFlight(request=_req(0, budget=10**9), slot=0, admit_time=0.0)
    t = 0.0
    slo.on_first_token(inf, t)
    for g in gaps:
        t += g
        slo.on_token(inf, t)
    s = slo.summary()
    assert s["token_latency_p50"] == pytest.approx(np.quantile(gaps, 0.5), rel=1e-9)
    assert s["token_latency_p99"] == pytest.approx(np.quantile(gaps, 0.99), rel=1e-9)


def test_slo_request_spans_disjoint_per_slot():
    """One slot serves requests back-to-back: the per-slot track passes the
    existing no-overlap gate even when spans touch exactly."""
    obs = Observability.create()
    slo = SLOTracker(obs.metrics, trace=obs.trace, track="host0/requests")
    t = 1000.0  # large base stresses the µs-rounding path
    for rid in range(20):
        inf = InFlight(request=_req(rid, t=t, budget=1), slot=0, admit_time=t)
        slo.on_first_token(inf, t + 0.0333)
        t += 0.0333  # next admit at exactly the previous completion
        slo.on_complete(inf, t)
    payload = obs.trace.to_chrome_trace()
    validate_no_overlap(payload, track_prefix="host0/requests")
    assert len(spans_by_track(payload)["host0/requests/slot0"]) == 20


def test_quantize_sim_span_touching_stays_touching():
    start, dur = 18.079207209, 0.000466667
    s1, d1 = quantize_sim_span(start, dur)
    s2, _ = quantize_sim_span(start + dur, dur)
    assert s1 + d1 <= s2 + 1e-12
    assert s1 == pytest.approx(start, abs=1e-9)
    assert d1 == pytest.approx(dur, abs=1e-9)


# ---------------------------------------------------------------------------
# Serving objective
# ---------------------------------------------------------------------------


def test_slo_objective_pressure_gating():
    from repro.launch.train_adaptive import fig10_parts

    _, _, cands, _ = fig10_parts(4)
    k1 = next(c for c in cands if c.k == 1)
    k2 = next(c for c in cands if c.k == 2)
    pressure = {"v": 0.0}
    obj = make_slo_objective(lambda: pressure["v"], latency_weight=2.0)
    # slack queue: grouped plans pay the emission-delay penalty
    assert obj(k1, 1.0, 0.0) == pytest.approx(1.0)
    assert obj(k2, 1.0, 0.0) == pytest.approx(1.0 + 2.0 * (2 - 1) / k2.num_microbatches)
    # saturated queue: pure makespan, no penalty
    pressure["v"] = 1.0
    assert obj(k2, 1.0, 0.0) == pytest.approx(1.0)
    # over-saturated clamps the same way
    pressure["v"] = 7.0
    assert obj(k2, 1.0, 0.0) == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# Fused prefill == token-stepping (model level)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "arch", ["qwen2.5-14b", "jamba-v0.1-52b", "gemma3-12b"]
)  # dense, attn/ssm hybrid, windowed attention
def test_prefill_with_cache_matches_token_stepping(arch):
    cfg = get_arch(arch).smoke
    B, P, L = 2, 6, 10
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, P)), jnp.int32)

    cache = api.init_cache(cfg, B, L)
    logits, cache = api.prefill_with_cache(params, cfg, cache, {"tokens": prompts})

    ref = api.init_cache(cfg, B, L)
    for i in range(P):
        ref_logits, ref = api.decode_fn(params, cfg, ref, i, {"tokens": prompts[:, i : i + 1]})
    np.testing.assert_array_equal(np.asarray(logits), np.asarray(ref_logits))
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        cache,
        ref,
    )
    # and the next decode step from both caches agrees
    tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
    nl, _ = api.decode_fn(params, cfg, cache, P, {"tokens": tok})
    rl, _ = api.decode_fn(params, cfg, ref, P, {"tokens": tok})
    np.testing.assert_array_equal(np.asarray(nl), np.asarray(rl))


def test_prefill_with_cache_rejects_unsupported_families():
    cfg = get_arch("seamless-m4t-medium").smoke
    with pytest.raises(NotImplementedError):
        api.prefill_with_cache({}, cfg, {}, {"tokens": jnp.zeros((1, 4), jnp.int32)})


# ---------------------------------------------------------------------------
# Decode workload asymmetry through derive_stage_costs
# ---------------------------------------------------------------------------


def test_decode_prefill_workload_asymmetry():
    import os

    spec = load_device_spec(os.path.join(spec_root(), "tpu-v5e.json"))
    root = os.path.join(spec_root(), "workloads")
    wl_dec = load_workload_profile(os.path.join(root, "pinned-4stage-decode.json"))
    wl_pre = load_workload_profile(os.path.join(root, "pinned-4stage-prefill.json"))
    dec = derive_stage_costs(wl_dec, spec)
    pre = derive_stage_costs(wl_pre, spec)
    assert len(dec.fwd_time) == 4 == len(pre.fwd_time)
    # decode is memory-bound: arithmetic intensity way below prefill's
    for s in range(4):
        fwd_dec, fwd_pre = wl_dec.counts[s]["fwd"], wl_pre.counts[s]["fwd"]
        ai_dec = fwd_dec.flops / fwd_dec.hbm_bytes
        ai_pre = fwd_pre.flops / fwd_pre.hbm_bytes
        assert ai_dec < 5.0 < ai_pre
        # per-token decode moves ~the same HBM traffic as the 16-token
        # prefill (weights dominate), so fwd times are within ~2x while
        # prefill carries 16x the FLOPs
        assert pre.fwd_time[s] < 2.0 * dec.fwd_time[s]
        assert fwd_pre.flops > 10.0 * fwd_dec.flops
    # activation handoffs: full-sequence prefill ships seq_len x decode's
    assert pre.fwd_bytes[0] == 16.0 * dec.fwd_bytes[0]


# ---------------------------------------------------------------------------
# Stateless PlanRuntime serving mode
# ---------------------------------------------------------------------------


def _tiny_cfg():
    from repro.models.common import ModelConfig

    return ModelConfig(
        name="serve-tiny", family="dense", num_layers=2, d_model=8,
        num_heads=2, num_kv_heads=2, d_ff=16, vocab_size=32,
        dtype=jnp.float32, param_dtype=jnp.float32,
    )


def test_plan_runtime_stateless_requires_factory():
    from repro.runtime import PlanRuntime

    with pytest.raises(ValueError, match="program_factory"):
        PlanRuntime(_tiny_cfg(), 2, optimizer=None, global_batch=4, seq_len=8)


def test_plan_runtime_stateless_run_program():
    from repro.core import make_plan
    from repro.runtime import PlanRuntime

    def factory(table):
        scale = float(table.plan.num_microbatches)

        def fn(x):
            return x * scale

        return jax.jit(fn), (jax.ShapeDtypeStruct((4,), jnp.float32),)

    rt = PlanRuntime(
        _tiny_cfg(), 2, optimizer=None, global_batch=4, seq_len=8,
        program_factory=factory,
    )
    assert rt.state is None
    with pytest.raises(RuntimeError, match="switch_to"):
        rt.run_program(jnp.ones((4,), jnp.float32))
    with pytest.raises(RuntimeError, match="run_program"):
        rt.run_iteration(jnp.zeros((4, 8), jnp.int32), jnp.zeros((4, 8), jnp.int32))
    p1 = make_plan(2, 2, 1).lower()
    p2 = make_plan(2, 4, 1).lower()
    rt.switch_to(p1)
    out, seconds = rt.run_program(jnp.ones((4,), jnp.float32))
    np.testing.assert_allclose(np.asarray(out), 2.0 * np.ones(4))
    assert seconds >= 0.0
    # warm switch to a different plan re-dispatches the cached program and
    # never touches (nonexistent) train state
    rt.switch_to(p2)
    out, _ = rt.run_program(jnp.ones((4,), jnp.float32))
    np.testing.assert_allclose(np.asarray(out), 4.0 * np.ones(4))
    rt.cache.shutdown()


# ---------------------------------------------------------------------------
# The serving scenario: acceptance observables (seeded, simulated clock)
# ---------------------------------------------------------------------------


def _small_run(adaptive: bool, regime: str = "fig10", requests: int = 24, seed: int = 0):
    from repro.launch.serve_adaptive import build_serve_scenario

    sc = build_serve_scenario(regime=regime, seed=seed, adaptive=adaptive)
    summary = sc.runtime.run(requests)
    return sc, summary


def test_serve_runtime_completes_and_accounts():
    sc, s = _small_run(adaptive=True)
    assert s["requests_completed"] == 24
    assert s["requests_admitted"] >= s["requests_completed"]
    done = sc.runtime.completed
    assert all(inf.tokens_emitted == inf.request.max_new_tokens for inf in done)
    assert s["ticks"] == s["decode_ticks"] + s["prefill_ticks"]
    assert s["prefill_ticks"] >= 1 and s["decode_ticks"] >= 1
    assert s["sim_time"] > 0 and s["tokens_per_second"] > 0
    # deterministic under the simulated clock
    _, s2 = _small_run(adaptive=True)
    assert s2 == s


def test_serve_tuner_crosses_kinds_and_uses_serve_telemetry():
    sc, s = _small_run(adaptive=True, requests=40)
    assert len(s["kinds_chosen"]) >= 2, s["kinds_chosen"]
    assert len(s["decision_trail"]) >= 2
    # the profiler windows were fed by this loop's own serve-sourced ticks
    assert len(sc.bus.history) > 0
    assert all(t.source == "serve" for t in sc.bus.history)
    assert s["tuning_overhead_charged"] < 0.05 * s["sim_time"]


def test_serve_static_baseline_never_switches():
    sc, s = _small_run(adaptive=False)
    assert s["decision_trail"] == []
    assert s["kinds_chosen"] == []
    assert all(t.kind == "kfkb" and t.k == 1 for t in sc.runtime.ticks)


def test_serve_chosen_spec_diverges_across_regimes():
    _, bursty = _small_run(adaptive=True, regime="bursty", requests=24)
    _, excl = _small_run(adaptive=True, regime="exclusive", requests=24)
    b_final = bursty["decision_trail"][-1]
    e_final = excl["decision_trail"][-1]
    assert b_final["chosen"] != e_final["chosen"]
    # preempted network favors the deep-warmup zero-bubble member;
    # an exclusive network frees the tuner to pick the interleaved member
    assert b_final["kind"] == "zb_h2"
    assert e_final["kind"] == "interleaved_zb"


def test_serve_trace_tracks_pass_no_overlap_gate():
    sc, _ = _small_run(adaptive=True)
    payload = sc.obs.trace.to_chrome_trace()
    validate_no_overlap(payload, track_prefix="host0")
    tracks = spans_by_track(payload)
    assert any(t.startswith("host0/requests/slot") for t in tracks)
    assert "host0/ticks" in tracks
