"""§4.2 memory-limit-curve enumeration properties."""

import pytest
from _hyp import given, settings, st  # hypothesis optional: property tests skip cleanly

from repro.core import MemoryModel, ScheduleSpec, SearchSpace, enumerate_candidates
from repro.core.schedule import make_plan


def _model(S=4, seq=128):
    return MemoryModel.uniform(
        num_stages=S,
        seq_len=seq,
        param_bytes=1e6,
        optimizer_bytes=2e6,
        grad_bytes=1e6,
        stage_input_bytes_per_token=256.0,
        layer_act_bytes_per_token=128.0,
        num_layers_per_stage=2,
    )


def test_candidates_on_curve_are_maximal():
    """For every candidate (k, b): b is the LARGEST feasible micro-batch —
    the next divisor up must violate memory (Fig 3: only curve points)."""
    S, B = 4, 64
    mm = _model(S)
    limit = 2e9
    cands = enumerate_candidates(
        S, B, mm, limit,
        space=SearchSpace(max_k=8),
    )
    assert cands, "no candidates found"
    divisors = [d for d in range(1, B + 1) if B % d == 0]
    for c in cands:
        assert c.est_peak_bytes <= limit
        bigger = [b for b in divisors if b > c.micro_batch_size]
        for b in bigger:
            M = B // b
            if M % c.k or M < S:
                continue
            plan = make_plan(S, M, c.k, micro_batch_size=b)
            assert mm.peak_bytes(plan) > limit  # larger b would OOM
            break  # only need the immediate next point


def test_k1_always_first_candidate_when_anything_fits():
    S, B = 4, 64
    cands = enumerate_candidates(
        S, B, _model(S), 2e9,
        space=SearchSpace(max_k=8),
    )
    assert cands[0].k == 1  # 1F1B is the most memory-efficient (paper §3.1)


def test_no_candidates_when_limit_too_small():
    S, B = 4, 64
    cands = enumerate_candidates(
        S, B, _model(S), 1e3,
        space=SearchSpace(max_k=8),
    )
    assert cands == []


@given(st.integers(2, 6), st.integers(4, 7).map(lambda e: 2 ** e))
@settings(max_examples=20, deadline=None)
def test_b_nonincreasing_in_k(S, B):
    """Paper §3.1: 'a larger k value is always paired with a smaller b'."""
    if B < S:
        B = S * 4
    cands = enumerate_candidates(
        S, B, _model(S), 1.5e9,
        space=SearchSpace(max_k=8),
    )
    by_k = {c.k: c.micro_batch_size for c in cands}
    ks = sorted(by_k)
    for a, b in zip(ks, ks[1:]):
        assert by_k[b] <= by_k[a]


def test_memory_model_k_monotonicity():
    mm = _model(4)
    plans = [make_plan(4, 16, k, micro_batch_size=4) for k in (1, 2, 4, 8, 16)]
    peaks = [mm.peak_bytes(p) for p in plans]
    assert peaks == sorted(peaks)  # more grouping -> more live activations


def test_checkpoint_policy_ordering():
    stage_input = _model(4)
    full = _model(4)
    full.checkpoint_policy = "full"
    plan = make_plan(4, 16, 2, micro_batch_size=4)
    assert full.peak_bytes(plan) > stage_input.peak_bytes(plan)


# -- saved-residual zero-bubble pricing (ROADMAP open item: price the vjp
# -- residual variant BEFORE anyone implements the engine change) ------------


def _model_policy(zb_policy, S=4):
    return MemoryModel.uniform(
        num_stages=S, seq_len=128, param_bytes=1e6, optimizer_bytes=2e6,
        grad_bytes=1e6, stage_input_bytes_per_token=256.0,
        layer_act_bytes_per_token=128.0, num_layers_per_stage=2,
        zb_policy=zb_policy,
    )


def test_saved_residual_surcharge_is_exactly_the_residual_bytes():
    """Per live zb slot, saved_residual keeps B's vjp residuals (one layer
    activation per stage layer) on top of the double-remat slot; non-zb
    slots are unaffected."""
    dr, sr = _model_policy("double_remat"), _model_policy("saved_residual")
    b = 4
    tokens = b * dr.seq_len
    spec = dr.stages[0]
    expected = spec.layer_act_bytes_per_token * spec.num_layers * tokens
    assert sr.slot_bytes(0, b, zb=True) - dr.slot_bytes(0, b, zb=True) == expected
    assert sr.slot_bytes(0, b, zb=False) == dr.slot_bytes(0, b, zb=False)


def test_saved_residual_under_full_checkpointing_fails_closed():
    """"full" checkpointing already keeps every layer activation resident,
    so saved_residual has nothing to buy there — the combination used to
    price a silent zero surcharge; now it is rejected both at construction
    and at use (checkpoint_policy is a mutable field)."""
    with pytest.raises(ValueError, match="redundant"):
        MemoryModel.uniform(
            num_stages=4, seq_len=128, param_bytes=1e6, optimizer_bytes=2e6,
            grad_bytes=1e6, stage_input_bytes_per_token=256.0,
            layer_act_bytes_per_token=128.0, num_layers_per_stage=2,
            checkpoint_policy="full", zb_policy="saved_residual",
        )
    sr_full = _model_policy("saved_residual")
    sr_full.checkpoint_policy = "full"  # post-construction mutation
    with pytest.raises(ValueError, match="redundant"):
        sr_full.slot_bytes(0, 4, zb=True)
    dr = _model_policy("double_remat")
    with pytest.raises(ValueError, match="redundant"):
        # per-call per-stage override hits the same guard
        dr.checkpoint_policy = "full"
        dr.slot_bytes(0, 4, zb=True, policy="saved_residual")


def test_full_checkpointing_with_double_remat_still_prices():
    """The non-redundant branch stays legal: full + double_remat prices the
    zb slot as the (full) activation store plus the stashed dy."""
    dr = _model_policy("double_remat")
    dr.checkpoint_policy = "full"
    b = 4
    tokens = b * dr.seq_len
    spec = dr.stages[0]
    dy = spec.stage_input_bytes_per_token * tokens
    assert dr.slot_bytes(0, b, zb=True) - dr.slot_bytes(0, b, zb=False) == dy


def test_saved_residual_rejected_under_limit_that_admits_double_remat():
    """The whole point of pricing first: a limit curve sized to admit the
    engine's double-remat H2 must shrink (or refuse) the saved-residual
    variant's candidates — the enumeration rejects it before any engine
    work happens."""
    S, B = 4, 32
    dr, sr = _model_policy("double_remat", S), _model_policy("saved_residual", S)
    h1 = make_plan(S, B, spec=ScheduleSpec(kind="zb_h1"))
    # one extra double-remat slot of headroom per stage: admits w=1 under
    # double_remat, not under the residual-fattened slot
    limits = [
        p + 1.5 * dr.slot_bytes(s, 1, True)
        for s, p in enumerate(dr.peak_bytes_per_stage(h1))
    ]
    dr_cands = enumerate_candidates(
        S, B, dr, limits,
        space=SearchSpace(kinds=("zb_h2",), max_k=1),
    )
    sr_cands = enumerate_candidates(
        S, B, sr, limits,
        space=SearchSpace(kinds=("zb_h2",), max_k=1),
    )
    assert dr_cands and max(dr_cands[0].extra_warmup) >= 1
    sr_names = {c.name for c in sr_cands}
    assert not (sr_names & {c.name for c in dr_cands}), (
        "saved_residual admitted the same deep-warmup plan the limit only "
        "affords under double_remat"
    )


def test_unknown_zb_policy_fails_closed():
    with pytest.raises(ValueError, match="zb_policy"):
        _model_policy("store_everything")


def test_saved_residual_requires_a_split_backward_kind():
    """Non-ZB kinds have no BWD_WEIGHT to skip a remat in: the spec fails
    closed at resolve time and the error names the kinds that qualify."""
    from repro.core.kinds import saved_residual_kinds

    kinds = saved_residual_kinds()
    assert set(kinds) == {"zb_h1", "zb_h2", "interleaved_zb", "zbv"}
    for bad in ("kfkb", "interleaved"):
        with pytest.raises(ValueError) as ei:
            make_plan(4, 8, spec=ScheduleSpec(
                kind=bad, num_virtual=2 if bad == "interleaved" else 1,
                zb_policy="saved_residual",
            ))
        for good in kinds:
            assert good in str(ei.value)


def test_sr_plan_peak_matches_exact_liveness_under_surcharge():
    """An SR plan's priced peak is EXACTLY the closed-form stage curve at
    the plan's exact live-slot count — the policy fattens the slot, never
    the liveness — and sits strictly above the same plan priced DR."""
    from repro.core.memory_model import predicted_peak_live
    from repro.core.schedule import peak_live_activations

    S, M = 4, 8
    mm = _model_policy("double_remat", S)
    sr = make_plan(S, M, spec=ScheduleSpec(kind="zb_h1", zb_policy="saved_residual"))
    dr = make_plan(S, M, spec=ScheduleSpec(kind="zb_h1"))
    live = peak_live_activations(sr)
    assert live == peak_live_activations(dr)  # identical schedule shape
    assert live == predicted_peak_live(sr)  # zb_h1's contract is exact
    peaks = mm.peak_bytes_per_stage(sr)
    for s in range(S):
        assert peaks[s] == mm.bytes_at_live(s, 1, live[s], True, policy="saved_residual")
    assert all(a > b for a, b in zip(peaks, mm.peak_bytes_per_stage(dr)))


def test_enumeration_chooses_policy_per_stage_against_the_curve():
    """The acceptance shape: a limit curve that is tight on stage 0 and
    generous elsewhere makes the enumeration emit the DR baseline plus a
    MIXED vector — saved_residual exactly on the admitting stages."""
    S, B = 4, 32
    mm = _model_policy("double_remat", S)
    h1 = make_plan(S, B, spec=ScheduleSpec(kind="zb_h1"))
    base = mm.peak_bytes_per_stage(h1)
    limits = [p + (1.0 if s == 0 else 1e9) for s, p in enumerate(base)]
    cands = enumerate_candidates(
        S, B, mm, limits,
        space=SearchSpace(
            kinds=("zb_h1",), max_k=1,
            zb_policies=("double_remat", "saved_residual"),
        ),
    )
    pols = {tuple(c.plan.zb_policy) for c in cands}
    assert ("double_remat",) * S in pols  # the baseline survives
    mixed = [p for p in pols if set(p) == {"double_remat", "saved_residual"}]
    assert mixed, f"no mixed per-stage vector enumerated: {pols}"
    for p in mixed:
        assert p[0] == "double_remat"  # the tight stage keeps DR
        assert p[1:] == ("saved_residual",) * (S - 1)


def test_sr_candidates_carry_their_policy_in_the_name():
    """Estimate keys and compile-cache keys go through the plan name: SR
    variants must be distinguishable from their DR siblings."""
    S, B = 4, 32
    mm = _model_policy("double_remat", S)
    cands = enumerate_candidates(
        S, B, mm, 1e12,
        space=SearchSpace(
            kinds=("zb_h1",), max_k=1,
            zb_policies=("double_remat", "saved_residual"),
        ),
    )
    names = [c.name for c in cands]
    assert len(set(names)) == len(names)
    assert any("+SR" in n for n in names)
    for c in cands:
        if "+SR" in c.name:
            assert "saved_residual" in c.plan.zb_policy
            assert c.spec.zb_policy == tuple(c.plan.zb_policy)
