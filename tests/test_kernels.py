"""Pallas kernel sweeps (interpret mode on CPU) vs pure-jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ref as flash_ref
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.ssd_scan import ref as ssd_ref
from repro.kernels.ssd_scan.kernel import ssd_chunked_pallas


def _mk_qkv(key, B, T, S, H, hd, dtype):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, T, H, hd), dtype)
    k = jax.random.normal(kk, (B, S, H, hd), dtype)
    v = jax.random.normal(kv, (B, S, H, hd), dtype)
    return q, k, v


FLASH_CASES = [
    # (T, S, hd, causal, window, block_q, block_k, dtype, tol)
    (64, 64, 32, True, None, 32, 32, jnp.float32, 2e-6),
    (128, 128, 64, True, None, 64, 64, jnp.float32, 2e-6),
    (96, 96, 32, True, None, 32, 32, jnp.float32, 2e-6),  # padding path
    (64, 64, 32, False, None, 32, 32, jnp.float32, 2e-6),
    (128, 128, 32, True, 48, 32, 32, jnp.float32, 2e-6),  # sliding window
    (64, 64, 64, True, None, 32, 32, jnp.bfloat16, 2e-2),
    (64, 64, 32, True, 16, 32, 16, jnp.bfloat16, 2e-2),
]


@pytest.mark.parametrize("T,S,hd,causal,window,bq,bk,dtype,tol", FLASH_CASES)
def test_flash_attention_vs_oracle(T, S, hd, causal, window, bq, bk, dtype, tol):
    B, H = 2, 3
    q, k, v = _mk_qkv(jax.random.PRNGKey(0), B, T, S, H, hd, dtype)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    out = flash_attention_pallas(
        qf, kf, vf, causal=causal, window=window,
        block_q=bq, block_k=bk, interpret=True,
    )
    ref = flash_ref.attention(q, k, v, causal=causal, window=window)
    ref = ref.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


SSD_CASES = [
    # (B, T, H, P, N, chunk, dtype, tol)
    (2, 32, 4, 16, 8, 8, jnp.float32, 1e-4),
    (1, 64, 2, 32, 16, 16, jnp.float32, 1e-4),
    (2, 64, 4, 64, 128, 32, jnp.float32, 1e-3),  # production-ish N
    (2, 32, 4, 16, 8, 8, jnp.bfloat16, 5e-2),
    (1, 16, 8, 8, 4, 16, jnp.float32, 1e-4),  # chunk == T
]


@pytest.mark.parametrize("B,T,H,P,N,chunk,dtype,tol", SSD_CASES)
def test_ssd_kernel_vs_sequential_oracle(B, T, H, P, N, chunk, dtype, tol):
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, T, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H), jnp.float32))
    A = -jnp.abs(jax.random.normal(ks[2], (H,), jnp.float32)) - 0.1
    Bm = jax.random.normal(ks[3], (B, T, N), jnp.float32)
    Cm = jax.random.normal(ks[4], (B, T, N), jnp.float32)
    out = ssd_chunked_pallas(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    ref = ssd_ref.ssd_reference(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


@pytest.mark.slow
def test_ssd_chunked_jnp_matches_sequential():
    """The chunked jnp path (what models run on CPU) vs the recurrence."""
    key = jax.random.PRNGKey(2)
    ks = jax.random.split(key, 5)
    B, T, H, P, N = 2, 48, 3, 16, 8
    x = jax.random.normal(ks[0], (B, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
    A = -jnp.abs(jax.random.normal(ks[2], (H,))) - 0.1
    Bm = jax.random.normal(ks[3], (B, T, 1, N))
    Cm = jax.random.normal(ks[4], (B, T, 1, N))
    out = ssd_ref.ssd_chunked(x, dt, A, Bm, Cm, chunk=16)
    ref = ssd_ref.ssd_reference(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-4)


def test_flash_wrapper_layout_roundtrip():
    from repro.kernels.flash_attention.ops import flash_attention

    B, T, H, hd = 2, 64, 4, 32
    q, k, v = _mk_qkv(jax.random.PRNGKey(3), B, T, T, H, hd, jnp.float32)
    out = flash_attention(q, k, v, causal=True, force_kernel=True)
    ref = flash_ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6, rtol=2e-6)
