"""Substrate: optimizers, schedules, data pipeline, checkpointing."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis optional: property tests skip cleanly

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.data import Batch, SyntheticTextDataset, microbatch_split
from repro.models import api
from repro.models.common import ModelConfig
from repro.optim import (
    adafactor_init,
    adafactor_update,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    cosine_schedule,
    global_norm,
    linear_warmup_cosine,
    make_optimizer,
)
from repro.training import create_train_state, make_train_step


def _tiny_cfg():
    return ModelConfig(
        "tiny", "dense", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=256,
        dtype=jnp.float32, param_dtype=jnp.float32,
    )


# -- optimizers ----------------------------------------------------------------


def _quadratic(params):
    return sum(jnp.sum(jnp.square(p)) for p in jax.tree_util.tree_leaves(params))


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_minimizes_quadratic(name):
    params = {"w": jnp.full((8, 8), 3.0), "b": jnp.full((8,), -2.0)}
    opt = make_optimizer(name, schedule=lambda s: jnp.float32(0.05), weight_decay=0.0)
    state = opt.init(params)
    for _ in range(200):
        grads = jax.grad(_quadratic)(params)
        params, state, _ = opt.update(params, grads, state)
    assert _quadratic(params) < 0.2


def test_adamw_bias_correction_first_step():
    params = {"w": jnp.zeros((4,))}
    grads = {"w": jnp.ones((4,))}
    state = adamw_init(params)
    new, _ = adamw_update(params, grads, state, lr=0.1, weight_decay=0.0)
    # first step with bias correction: update == lr * g / (|g| + eps) = -0.1
    np.testing.assert_allclose(np.asarray(new["w"]), -0.1, rtol=1e-5)


def test_adafactor_factored_state_shapes():
    params = {"w": jnp.zeros((12, 8)), "b": jnp.zeros((8,))}
    state = adafactor_init(params)
    assert state.v_row["w"].shape == (12,)
    assert state.v_col["w"].shape == (8,)
    assert state.v_row["b"].shape == (8,)  # rank-1: full second moment


def test_adafactor_memory_is_sublinear():
    n = 64
    params = {"w": jnp.zeros((n, n))}
    st_af = adafactor_init(params)
    af_size = sum(x.size for x in jax.tree_util.tree_leaves((st_af.v_row, st_af.v_col)))
    st_aw = adamw_init(params)
    aw_size = sum(x.size for x in jax.tree_util.tree_leaves((st_aw.m, st_aw.v)))
    assert af_size == 2 * n and aw_size == 2 * n * n


def test_clipping():
    g = {"a": jnp.full((10,), 3.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(float(jnp.sqrt(90.0)))
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    small = {"a": jnp.full((4,), 0.01)}
    same, _ = clip_by_global_norm(small, 1.0)
    np.testing.assert_allclose(np.asarray(same["a"]), 0.01, rtol=1e-6)


def test_schedules():
    sch = linear_warmup_cosine(1.0, warmup_steps=10, total_steps=110, final_frac=0.1)
    assert float(sch(jnp.int32(0))) == 0.0
    assert float(sch(jnp.int32(10))) == pytest.approx(1.0, rel=1e-3)
    assert float(sch(jnp.int32(110))) == pytest.approx(0.1, rel=1e-2)
    cos = cosine_schedule(2.0, 100)
    assert float(cos(jnp.int32(0))) == pytest.approx(2.0)


# -- data -----------------------------------------------------------------------


def test_dataset_deterministic_and_learnable():
    ds = SyntheticTextDataset(256, 32, 8, seed=3)
    a, b = ds.batch_at(5), ds.batch_at(5)
    np.testing.assert_array_equal(np.asarray(a.tokens), np.asarray(b.tokens))
    assert not np.array_equal(np.asarray(a.tokens), np.asarray(ds.batch_at(6).tokens))
    # labels are the shifted stream
    full = np.asarray(a.tokens)
    lab = np.asarray(a.labels)
    assert lab.shape == full.shape


@given(st.sampled_from([1, 2, 4, 8]))
@settings(max_examples=8, deadline=None)
def test_microbatch_split_partitions(M):
    ds = SyntheticTextDataset(128, 16, 8, seed=0)
    b = ds.batch_at(0)
    parts = microbatch_split(b, M)
    assert len(parts) == M
    recon = np.concatenate([np.asarray(p.tokens) for p in parts], axis=0)
    np.testing.assert_array_equal(recon, np.asarray(b.tokens))


def test_train_loss_decreases_e2e():
    cfg = _tiny_cfg()
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    # 5e-3 (not 2e-3): the structured synthetic stream needs the larger step
    # to clear the 0.1 margin within 40 steps on CPU
    opt = make_optimizer("adamw", linear_warmup_cosine(5e-3, 5, 60))
    state = create_train_state(params, opt)
    step = jax.jit(make_train_step(lambda p, b: api.loss_fn(p, cfg, b), opt,
                                   num_microbatches=2))
    ds = SyntheticTextDataset(cfg.vocab_size, 32, 8, seed=1)
    losses = []
    for i in range(40):
        b = ds.batch_at(i)
        state, m = step(state, {"tokens": b.tokens, "labels": b.labels})
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1


def test_grad_accum_matches_full_batch():
    """M accumulated micro-batch gradients == the full-batch gradient.

    Gradients, not post-Adam params: the bias-corrected first Adam step is
    ~sign(g), which amplifies reduction-order noise on near-zero grads."""
    from repro.training.steps import _reshape_microbatches

    cfg = _tiny_cfg()
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    ds = SyntheticTextDataset(cfg.vocab_size, 16, 8, seed=2)
    b = ds.batch_at(0)
    batch = {"tokens": b.tokens, "labels": b.labels}

    def full(p):
        return api.loss_fn(p, cfg, batch)[0]

    def accum(p):
        stacked = _reshape_microbatches(batch, 4)
        losses = [
            api.loss_fn(p, cfg, {k: v[i] for k, v in stacked.items()})[0]
            for i in range(4)
        ]
        return sum(losses) / 4

    l1, g1 = jax.value_and_grad(full)(params)
    l4, g4 = jax.value_and_grad(accum)(params)
    assert float(l1) == pytest.approx(float(l4), rel=1e-5)
    for a, c in zip(jax.tree_util.tree_leaves(g1), jax.tree_util.tree_leaves(g4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=2e-5)


# -- checkpoint -------------------------------------------------------------------


def test_checkpoint_roundtrip_and_latest():
    cfg = _tiny_cfg()
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer("adamw")
    state = create_train_state(params, opt)
    with tempfile.TemporaryDirectory() as d:
        assert latest_step(d) is None
        save_checkpoint(d, 10, state)
        save_checkpoint(d, 20, state)
        assert latest_step(d) == 20
        restored = load_checkpoint(d, 20, state)
        for a, b in zip(jax.tree_util.tree_leaves(state), jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_missing_leaf_raises():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, {"a": jnp.zeros((2,))})
        with pytest.raises(KeyError):
            load_checkpoint(d, 1, {"a": jnp.zeros((2,)), "b": jnp.zeros((2,))})
