"""The ScheduleSpec / SearchSpace / kind-registry API redesign, proven.

Five suites:

* **Back-compat conformance** — the legacy ``make_plan(**kwargs)`` /
  ``enumerate_candidates(kinds=..., virtual_degrees=...)`` signatures and
  the new ``spec=`` / ``space=`` forms produce IDENTICAL plans (same
  lowered ``TabularPlan`` digests) and identical candidate sets.  This
  file is the ONE place legacy forms are called on purpose (module-level
  ``filterwarnings`` below); everywhere else the gate test bites.
* **Deprecation contract** — the legacy forms warn ``DeprecationWarning``
  (PR 6), the modern forms stay silent, and mixing both is a loud error.
* **Fail-closed registry** — an unregistered kind is a loud ``ValueError``
  naming the registered kinds, everywhere a kind string enters the system.
* **No string dispatch / no legacy call forms** — the tier-1 gates: no
  module under ``src/repro`` outside ``core/kinds.py`` /
  ``core/schedule.py`` may dispatch on schedule-kind strings, and no
  in-repo caller outside this file may use the deprecated kwarg forms or
  the untyped Coordinator hooks (the CI lint job runs the same scans;
  these tests make them bite locally).
* **ZB-V acceptance** — the first registry-only family member shows the
  controllable-memory trade: peak live strictly below the equal-(S, M, k)
  plain-interleaved plan's, makespan no worse than 1F1B on the preemption
  traces, and full participation in candidate search, tuning records and
  the compile-cache key through the one ScheduleSpec currency.
"""

import ast
import hashlib
import os
import re
import warnings as _warnings

import pytest

# the conformance suite exercises the deprecated forms BY DESIGN; the
# explicit deprecation tests below re-enable the filter locally
pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")

from repro.core import (
    MemoryModel,
    ScheduleSpec,
    SearchSpace,
    StageCosts,
    enumerate_candidates,
    get_kind,
    known_kinds,
    make_plan,
    registered_kinds,
    simulate_plan,
    uniform_network,
)
from repro.core.network import PeriodicPreemptionTrace
from repro.core.schedule import peak_live_activations

_SRC = os.path.join(os.path.dirname(__file__), "..", "src", "repro")


def _digest(plan) -> str:
    table = plan.lower()
    edges = tuple(
        sorted(
            (e.src_stage, e.dst_stage, int(e.op), e.mb, e.src_chunk,
             e.dst_chunk, e.send_tick, e.recv_tick)
            for e in table.edges
        )
    )
    return hashlib.sha1(table.grid.tobytes() + repr(edges).encode()).hexdigest()


def _mm(S):
    return MemoryModel.uniform(
        num_stages=S, seq_len=64, param_bytes=1e6, optimizer_bytes=2e6,
        grad_bytes=1e6, stage_input_bytes_per_token=512.0,
        layer_act_bytes_per_token=64.0, num_layers_per_stage=2,
    )


# ---------------------------------------------------------------------------
# Back-compat conformance: legacy kwargs == ScheduleSpec / SearchSpace
# ---------------------------------------------------------------------------

_LEGACY_VS_SPEC = [
    (dict(k=1), ScheduleSpec()),
    (dict(k=2, micro_batch_size=2), ScheduleSpec(k=2, micro_batch_size=2)),
    (dict(k=1, kind="1f1b"), ScheduleSpec(kind="1f1b")),
    (dict(k=1, kind="gpipe"), ScheduleSpec(kind="gpipe")),
    (dict(k=2, kind="zb_h1"), ScheduleSpec(kind="zb_h1", k=2)),
    (dict(k=1, kind="zb_h2", extra_warmup=2),
     ScheduleSpec(kind="zb_h2", extra_warmup=2)),
    (dict(k=1, kind="zb_h2", extra_warmup=(0, 1, 0, 2)),
     ScheduleSpec(kind="zb_h2", extra_warmup=(0, 1, 0, 2))),
    (dict(k=2, kind="interleaved", num_virtual=2),
     ScheduleSpec(kind="interleaved", k=2, num_virtual=2)),
    (dict(k=1, kind="interleaved_zb", num_virtual=2, extra_warmup=1),
     ScheduleSpec(kind="interleaved_zb", num_virtual=2, extra_warmup=1)),
    (dict(k=1, kind="zbv"), ScheduleSpec(kind="zbv")),
    (dict(k=2, kind="zbv", extra_warmup=(1, 0, 2, 1)),
     ScheduleSpec(kind="zbv", k=2, extra_warmup=(1, 0, 2, 1))),
]


@pytest.mark.parametrize(
    "legacy,spec", _LEGACY_VS_SPEC,
    ids=[s.kind + f"-k{s.k}" for _, s in _LEGACY_VS_SPEC],
)
def test_make_plan_legacy_kwargs_equal_spec(legacy, spec):
    """Same coordinates, either calling convention -> the SAME lowered
    plan, bit for bit (grid + exact edge list)."""
    S, M = 4, 8
    old = make_plan(S, M, **legacy)
    new = make_plan(S, M, spec=spec)
    assert _digest(old) == _digest(new)
    assert old.name == new.name
    assert old.spec == new.spec


def test_make_plan_rejects_mixing_spec_and_kwargs():
    with pytest.raises(ValueError, match="not both"):
        make_plan(4, 8, 2, spec=ScheduleSpec(kind="zb_h1"))


def test_plan_spec_roundtrip():
    """plan.spec is normalized (aliases folded, w and zb_policy vectors)
    and rebuilding from it reproduces the plan."""
    plan = make_plan(4, 8, 1, kind="gpipe")
    assert plan.spec == ScheduleSpec(
        kind="kfkb", k=8, extra_warmup=(0,) * 4, zb_policy=("double_remat",) * 4
    )
    again = make_plan(4, 8, spec=plan.spec)
    assert _digest(plan) == _digest(again)


def test_enumerate_candidates_legacy_kwargs_equal_search_space():
    """The legacy axis kwargs and an explicit SearchSpace produce the same
    candidate list: same order, same coordinates, same lowered digests,
    same memory pricing."""
    S, B = 4, 32
    mm = _mm(S)
    kinds = ("kfkb", "zb_h1", "zb_h2", "interleaved", "interleaved_zb", "zbv")
    old = enumerate_candidates(
        S, B, mm, 1e8, max_k=4, kinds=kinds, virtual_degrees=(2,),
        max_extra_warmup=3,
    )
    new = enumerate_candidates(
        S, B, mm, 1e8,
        space=SearchSpace(
            kinds=kinds, virtual_degrees=(2,), max_k=4, max_extra_warmup=3
        ),
    )
    assert [c.name for c in old] == [c.name for c in new]
    assert [c.spec for c in old] == [c.spec for c in new]
    assert [c.est_peak_bytes for c in old] == [c.est_peak_bytes for c in new]
    assert [_digest(c.plan) for c in old] == [_digest(c.plan) for c in new]
    assert any(c.kind == "zbv" for c in new)  # the registry-only member searches


def test_candidate_record_cache_share_one_spec_currency():
    """Candidate.spec == TuningRecord.chosen_spec == the ScheduleSpec
    inside the compile-cache key: one currency end to end."""
    from repro.core import AutoTuner, NetworkProfiler, StableTrace
    from repro.runtime.compile_cache import CompiledStepCache

    S, B = 4, 32
    cands = enumerate_candidates(
        S, B, _mm(S), 1e8, max_k=2, kinds=("kfkb", "zb_h1", "zbv"),
    )
    costs_for = lambda c: StageCosts.uniform(S, 0.1, act_bytes=1.0)  # noqa: E731
    net = uniform_network(S, lambda: StableTrace(100.0))
    tuner = AutoTuner(cands, costs_for, NetworkProfiler(net))
    rec = tuner.tune(now=0.0)
    winner = next(c for c in cands if c.name == rec.chosen)
    assert rec.chosen_spec == winner.spec == winner.plan.spec
    key = CompiledStepCache.plan_key(winner.table)
    assert winner.spec in key


# ---------------------------------------------------------------------------
# Deprecation contract (PR 6): legacy forms warn, modern forms are silent
# ---------------------------------------------------------------------------


def test_make_plan_legacy_kind_kwargs_warn():
    """The kind/num_virtual/extra_warmup kwargs emit DeprecationWarning
    pointing at spec=ScheduleSpec(...); the paper's original positional
    (S, M, k, micro_batch_size=b) form and the spec= form stay silent."""
    with pytest.warns(DeprecationWarning, match="spec=ScheduleSpec"):
        make_plan(4, 8, 1, kind="zb_h1")
    with pytest.warns(DeprecationWarning, match="deprecated"):
        make_plan(4, 8, 2, kind="interleaved", num_virtual=2)
    with pytest.warns(DeprecationWarning, match="deprecated"):
        make_plan(4, 8, 1, extra_warmup=1, kind="zb_h2")
    with _warnings.catch_warnings():
        _warnings.simplefilter("error")
        make_plan(4, 8, 2, micro_batch_size=2)  # paper form: not deprecated
        make_plan(4, 8, spec=ScheduleSpec(kind="zbv", k=2))


def test_enumerate_candidates_legacy_axis_kwargs_warn():
    """Each legacy axis kwarg triggers the warning (which names the kwargs
    given); space= and the bare 4-positional call stay silent."""
    mm = _mm(4)
    with pytest.warns(DeprecationWarning, match=r"max_k=.*space=SearchSpace"):
        enumerate_candidates(4, 16, mm, 1e9, max_k=1)
    with pytest.warns(DeprecationWarning, match="kinds="):
        enumerate_candidates(4, 16, mm, 1e9, kinds=("kfkb",))
    with _warnings.catch_warnings():
        _warnings.simplefilter("error")
        enumerate_candidates(4, 16, mm, 1e9)
        enumerate_candidates(4, 16, mm, 1e9, space=SearchSpace(max_k=1))


def test_enumerate_candidates_rejects_space_plus_legacy_axes():
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="not both"):
            enumerate_candidates(
                4, 16, _mm(4), 1e9, max_k=1, space=SearchSpace(max_k=1)
            )


# ---------------------------------------------------------------------------
# Fail-closed registry
# ---------------------------------------------------------------------------


def test_unknown_kind_fails_closed_everywhere():
    """An unregistered kind raises a ValueError NAMING the registered
    kinds — in the registry lookup, in make_plan, and in the candidate
    search; it is never silently skipped."""
    for call in (
        lambda: get_kind("zb_h3"),
        lambda: make_plan(4, 8, 1, kind="zb_h3"),
        lambda: make_plan(4, 8, spec=ScheduleSpec(kind="zb_h3")),
    ):
        with pytest.raises(ValueError, match="registered kinds") as ei:
            call()
        for kind in registered_kinds():
            assert kind in str(ei.value)
    with pytest.raises(ValueError, match="unknown schedule kind"):
        enumerate_candidates(4, 32, _mm(4), 1e8, kinds=("kfkb", "zb_h3"))


def test_known_kinds_covers_registry_and_aliases():
    """The candidate search accepts exactly the registry + aliases — a
    registered kind can never be rejected as unknown (the old hardcoded
    ``PLAN_KINDS + ("1f1b", "gpipe")`` tuple drifted by construction)."""
    ks = known_kinds()
    assert set(registered_kinds()) <= set(ks)
    assert {"1f1b", "gpipe"} <= set(ks)
    # every known name is accepted by the search (smoke: no ValueError)
    enumerate_candidates(4, 16, _mm(4), 1e9, max_k=1, kinds=ks)


def test_duplicate_registration_rejected():
    from repro.core import KindSpec, register_kind

    with pytest.raises(ValueError, match="already registered"):
        register_kind(
            KindSpec(
                name="kfkb",
                build_orders=lambda *a: [],
                peak_live_groups=lambda *a: [],
            )
        )


def test_capability_flags_gate_coordinates():
    """Coordinate validation is capability-driven: virtual degrees only on
    virtual-capable kinds, ZB-V pinned to 2 chunks, warmup only on
    warmup-capable kinds."""
    with pytest.raises(ValueError, match="interleaved kind"):
        make_plan(4, 8, 1, kind="zb_h1", num_virtual=2)
    with pytest.raises(ValueError, match="exactly 2 chunks"):
        make_plan(4, 8, 1, kind="zbv", num_virtual=3)
    assert make_plan(4, 8, 1, kind="zbv").num_virtual == 2  # coerced default


# ---------------------------------------------------------------------------
# The grep gate: no kind-string dispatch outside kinds.py / schedule.py
# ---------------------------------------------------------------------------

_ALLOWED = {os.path.join("core", "kinds.py"), os.path.join("core", "schedule.py")}
#: schedule-kind string dispatch (`plan.kind == "zb_h2"`-style ladders) or
#: membership tests against the legacy kind-set tuples
_DISPATCH = [
    re.compile(
        r"kind\s*(?:==|!=)\s*[\"']"
        r"(?:kfkb|zb_h1|zb_h2|interleaved|interleaved_zb|zbv|1f1b|gpipe)[\"']"
    ),
    re.compile(r"kind\s+(?:not\s+)?in\s+\("),
    re.compile(
        r"kind\s+(?:not\s+)?in\s+"
        r"(?:PLAN_KINDS|ZB_KINDS|INTERLEAVED_KINDS|WARMUP_KINDS)"
    ),
]


def test_no_kind_string_dispatch_outside_registry():
    """The redesign's lock: every schedule-kind decision outside the
    registry and the schedule module itself must go through KindSpec
    capability flags.  New ``kind == "..."`` ladders fail here (and the CI
    lint job runs the same scan)."""
    offenders = []
    for root, _, files in os.walk(_SRC):
        for f in files:
            if not f.endswith(".py"):
                continue
            path = os.path.join(root, f)
            rel = os.path.relpath(path, _SRC)
            if rel in _ALLOWED:
                continue
            with open(path) as fh:
                for i, line in enumerate(fh, 1):
                    if any(p.search(line) for p in _DISPATCH):
                        offenders.append(f"{rel}:{i}: {line.strip()}")
    assert not offenders, (
        "schedule-kind string dispatch outside core/kinds.py + "
        "core/schedule.py:\n" + "\n".join(offenders)
    )


#: deprecated kwarg sets per callee — a call site naming any of these is a
#: legacy form (AST-matched, so formatting/line-breaks can't hide one)
_LEGACY_FORMS = {
    "make_plan": {"kind", "num_virtual", "extra_warmup"},
    "enumerate_candidates": {
        "kinds", "virtual_degrees", "max_k", "min_microbatches", "max_extra_warmup"
    },
    "Coordinator": {"telemetry", "on_iteration"},
}
_REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
_ROOTS = [
    _SRC,
    os.path.join(os.path.dirname(__file__)),
    os.path.join(os.path.dirname(__file__), "..", "benchmarks"),
    os.path.join(os.path.dirname(__file__), "..", "examples"),
]
_LEGACY_EXEMPT = {os.path.abspath(__file__)}  # this suite calls them on purpose


def test_no_legacy_call_forms_outside_conformance_suite():
    """PR 6's migration lock: every in-repo caller of make_plan /
    enumerate_candidates / Coordinator uses the ScheduleSpec / SearchSpace
    / typed-hook forms.  The deprecated kwargs may appear only in this
    conformance suite.  AST-based so a reformatted call can't slip past
    the CI grep (which runs a coarser single-line scan of the same names
    for log visibility)."""
    offenders = []
    for base in _ROOTS:
        for root, _, files in os.walk(os.path.abspath(base)):
            for f in sorted(files):
                if not f.endswith(".py"):
                    continue
                path = os.path.join(root, f)
                if os.path.abspath(path) in _LEGACY_EXEMPT:
                    continue
                with open(path) as fh:
                    tree = ast.parse(fh.read())
                for node in ast.walk(tree):
                    if not isinstance(node, ast.Call):
                        continue
                    name = (
                        node.func.id if isinstance(node.func, ast.Name)
                        else node.func.attr if isinstance(node.func, ast.Attribute)
                        else None
                    )
                    banned = _LEGACY_FORMS.get(name)
                    if not banned:
                        continue
                    hit = banned & {kw.arg for kw in node.keywords if kw.arg}
                    if hit:
                        offenders.append(
                            f"{os.path.relpath(path, _REPO)}:{node.lineno}: "
                            f"{name}({', '.join(sorted(hit))}=...)"
                        )
    assert not offenders, (
        "deprecated legacy call forms outside tests/test_spec_api.py "
        "(use spec=ScheduleSpec / space=SearchSpace / hooks= / "
        "telemetry_sink=):\n" + "\n".join(offenders)
    )


# ---------------------------------------------------------------------------
# ZB-V: the registry-only member's acceptance gates
# ---------------------------------------------------------------------------


def test_zbv_peak_live_below_plain_interleaved():
    """The controllable-memory trade, memory half: at equal (S, M, k) the
    V placement's worst-device peak live count is strictly below plain
    interleaved's (whose looped placement forces the deep Megatron
    warmup) — and exactly the registered closed-form row prices it."""
    from repro.core import predicted_peak_live

    for S, M, k in ((4, 16, 1), (4, 32, 2), (8, 32, 1), (3, 12, 1)):
        zbv = make_plan(S, M, k, kind="zbv")
        il = make_plan(S, M, k, kind="interleaved", num_virtual=2)
        assert max(peak_live_activations(zbv)) < max(peak_live_activations(il))
        assert all(
            p <= pr
            for p, pr in zip(peak_live_activations(zbv), predicted_peak_live(zbv))
        )


def test_zbv_makespan_no_worse_than_1f1b_under_preemption():
    """The controllable-memory trade, time half: on the preemption traces
    ZB-V's simulated makespan is no worse than 1F1B's (the W filler +
    V-shaped turn absorb the stalls), despite holding ~half the
    plain-interleaved peak."""
    for S, M in ((4, 16), (8, 32), (3, 12)):
        costs = StageCosts.uniform(S, 1.0, act_bytes=1.0)

        def trace():
            return PeriodicPreemptionTrace(high=50.0, low=0.5, period=20.0, duty=0.3)

        len_1f1b = simulate_plan(
            make_plan(S, M, 1), costs, uniform_network(S, trace)
        ).pipeline_length
        len_zbv = simulate_plan(
            make_plan(S, M, 1, kind="zbv"), costs, uniform_network(S, trace)
        ).pipeline_length
        assert len_zbv <= len_1f1b * 1.001, (S, M, len_zbv, len_1f1b)


def test_zbv_lowered_plan_is_near_zero_bubble():
    """Unit-cost bubble fraction of the lowered V stays single-digit —
    the 2S-slot cap actually buys the zero-bubble operating point."""
    for S, M in ((4, 16), (8, 32)):
        stats = make_plan(S, M, 1, kind="zbv").lower().stats()
        assert stats["bubble_fraction"] < 0.10, (S, M, stats)


def test_zbv_weight_placement_refinable():
    """ZB-V's registry record opts into the W-placement refinement; the
    optimizer must preserve the task multiset and the plan's peak-live
    price on the V placement."""
    from repro.core import optimize_weight_placement

    plan = make_plan(4, 8, 1, kind="zbv")
    assert get_kind("zbv").weight_placement_refinable
    skew = StageCosts(
        fwd_time=[1.0, 0.8, 1.2, 0.9], bwd_time=[3.0, 2.0, 2.4, 2.8],
        fwd_bytes=[1.0] * 4, bwd_bytes=[1.0] * 4,
        bwd_input_time=[0.7, 1.1, 0.9, 1.3], bwd_weight_time=[2.3, 0.9, 1.5, 1.5],
    )
    bw = {(a, b): 2.0 for a in range(4) for b in range(4) if abs(a - b) == 1}
    opt = optimize_weight_placement(plan, skew, bw, evaluator="full")
    assert sorted(t.key() for o in opt.orders for t in o) == sorted(
        t.key() for o in plan.orders for t in o
    )
    assert max(peak_live_activations(opt)) <= max(peak_live_activations(plan))
    opt.lower().validate()
