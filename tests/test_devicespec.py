"""Device-spec files (repro.core.devicespec): the offline calibration substrate.

Four suites:

* **Fail-closed loading** — every malformed spec/workload file (wrong
  schema version, missing or negative fields, unknown dtype keys,
  non-monotone derating curves) is a loud :class:`DeviceSpecError` whose
  message names the file, the field, and what a valid value looks like.
  Silently defaulting any of these would fork the cost model invisibly.
* **Legacy equivalence** — the committed reference spec
  ``specs/tpu-v5e.json`` encodes exactly the legacy roofline constants,
  and its latency-padded derated pricing reduces **bit-for-bit** to the
  old ``max(flops/peak, bytes/bw)``.  This is what lets ``method="spec"``
  replace the baked-in constants without moving a single float.
* **Roofline-constant scan** — the tier-1 twin of the CI grep gate: no
  module outside ``core/devicespec.py`` may define
  ``PEAK_FLOPS``/``HBM_BW``/``LINK_BW``-style raw constants or spell the
  legacy magic numbers.  Hardware numbers belong in ``specs/*.json``.
* **Hardware-matrix conformance** — every committed spec's full
  derive → enumerate → tune → simulate slice matches its golden fixture
  in ``specs/golden/`` (the same check the CI ``hardware-matrix`` job
  runs one matrix cell per part).
"""

import json
import os
import re
import sys

import numpy as np
import pytest

from repro.core.devicespec import (
    HBM_BW,
    KNOWN_DTYPES,
    LINK_BW,
    PEAK_FLOPS,
    SPEC_SCHEMA_VERSION,
    TASK_PROGRAMS,
    DeviceSpec,
    DeviceSpecError,
    derive_memory_model,
    derive_stage_costs,
    dtype_key,
    load_device_spec,
    load_workload_profile,
    spec_root,
)

_REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _spec_payload(**over):
    """A fully valid spec payload; tests mutate one field at a time."""
    base = {
        "schema_version": SPEC_SCHEMA_VERSION,
        "name": "test-part",
        "peak_flops": {"bf16": 1e15, "f32": 5e14},
        "hbm_bandwidth_bytes_per_s": 1e12,
        "hbm_latency_s": 1e-6,
        "memory_capacity_bytes": 1.6e10,
        "link_bandwidth_bytes_per_s": 1e11,
        "link_latency_s": 2e-6,
        "derating": [[4096, 0.25], [1048576, 1.0]],
    }
    base.update(over)
    return base


# ---------------------------------------------------------------------------
# fail-closed loading
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "override, match",
    [
        ({"schema_version": 2}, r"schema_version 2 != supported 1"),
        ({"schema_version": "1"}, r"schema_version '1' != supported 1"),
        ({"name": ""}, r"'name' must be a non-empty string"),
        ({"peak_flops": {}}, r"'peak_flops' must be a non-empty"),
        ({"peak_flops": {"bf16": -1e15}}, r"peak_flops\['bf16'\].* positive"),
        (
            {"peak_flops": {"bf16": 1e15, "complex64": 1e15}},
            r"unknown peak_flops dtype key 'complex64'",
        ),
        ({"hbm_bandwidth_bytes_per_s": 0}, r"hbm_bandwidth.* positive"),
        ({"hbm_bandwidth_bytes_per_s": "fast"}, r"must be a number, got 'fast'"),
        ({"memory_capacity_bytes": -16e9}, r"memory_capacity_bytes.* positive"),
        ({"hbm_latency_s": -1e-9}, r"hbm_latency_s.* >= 0"),
        ({"derating": []}, r"'derating' must be a non-empty list"),
        ({"derating": [[4096]]}, r"derating\[0\] must be a \[bytes, efficiency\] pair"),
        ({"derating": [[4096, 1.5]]}, r"efficiency 1\.5 > 1\.0"),
        (
            {"derating": [[4096, 0.5], [4096, 0.6]]},
            r"bytes must be strictly increasing",
        ),
        (
            {"derating": [[4096, 0.9], [8192, 0.5]]},
            r"efficiency must be non-decreasing",
        ),
    ],
    ids=[
        "schema-version-mismatch", "schema-version-stringly", "empty-name",
        "empty-peaks", "negative-peak", "unknown-dtype-key", "zero-hbm-bw",
        "stringly-hbm-bw", "negative-capacity", "negative-latency",
        "empty-derating", "malformed-knot", "efficiency-above-one",
        "non-increasing-bytes", "decreasing-efficiency",
    ],
)
def test_spec_loading_fails_closed(override, match):
    with pytest.raises(DeviceSpecError, match=match):
        DeviceSpec.from_json(_spec_payload(**override), source="test.json")


@pytest.mark.parametrize(
    "missing",
    ["schema_version", "name", "peak_flops", "hbm_bandwidth_bytes_per_s",
     "memory_capacity_bytes", "link_bandwidth_bytes_per_s", "derating"],
)
def test_spec_missing_required_field_fails_closed(missing):
    payload = _spec_payload()
    del payload[missing]
    with pytest.raises(DeviceSpecError, match=f"missing required field {missing!r}"):
        DeviceSpec.from_json(payload, source="test.json")


def test_spec_error_message_names_the_file():
    """Actionability: the operator must learn WHICH file to fix."""
    with pytest.raises(DeviceSpecError, match=r"^broken\.json: "):
        DeviceSpec.from_json(_spec_payload(schema_version=99), source="broken.json")


def test_load_device_spec_missing_and_invalid_files(tmp_path):
    with pytest.raises(DeviceSpecError, match="device spec file not found"):
        load_device_spec(str(tmp_path / "nope.json"))
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    with pytest.raises(DeviceSpecError, match="not valid JSON"):
        load_device_spec(str(bad))


def test_unknown_compute_dtype_fails_closed():
    spec = DeviceSpec.from_json(_spec_payload())
    with pytest.raises(DeviceSpecError, match="no peak_flops entry for dtype 'f8e4m3fn'"):
        spec.task_seconds(1e12, 1e9, "f8e4m3fn")


def test_dtype_key_mapping_fails_closed():
    assert dtype_key(np.float32) == "f32"
    assert dtype_key("float16") == "f16"
    assert dtype_key(np.dtype("int8")) == "s8"
    with pytest.raises(DeviceSpecError, match="no spec dtype key for dtype 'int32'"):
        dtype_key(np.int32)
    assert KNOWN_DTYPES >= {"bf16", "f32", "tf32", "f8e4m3fn"}


# ---------------------------------------------------------------------------
# round trips + the committed fleet
# ---------------------------------------------------------------------------


def _committed_specs():
    import glob

    return sorted(glob.glob(os.path.join(spec_root(), "*.json")))


def test_committed_fleet_present_and_loadable():
    """The PR's shipped parts: >= 3 real + 2 synthetic, all valid, names
    matching their file stems (the hardware-matrix job keys on stems)."""
    paths = _committed_specs()
    names = {load_device_spec(p).name for p in paths}
    assert names == {os.path.splitext(os.path.basename(p))[0] for p in paths}
    assert {"tpu-v5e", "h100-sxm", "a100-40gb"} <= names  # real parts
    assert {"synthetic-extreme-skew", "synthetic-slow-interconnect"} <= names


@pytest.mark.parametrize("path", _committed_specs(),
                         ids=[os.path.basename(p) for p in _committed_specs()])
def test_spec_save_load_round_trip(path, tmp_path):
    spec = load_device_spec(path)
    out = tmp_path / os.path.basename(path)
    spec.save(str(out))
    assert load_device_spec(str(out)) == spec


def test_reference_spec_encodes_the_legacy_constants():
    """specs/tpu-v5e.json IS the legacy roofline as data: same three
    numbers, zero latency, flat 1.0 derating.  Everything the old in-code
    constants could express, expressed as a file."""
    spec = load_device_spec(os.path.join(spec_root(), "tpu-v5e.json"))
    assert spec.peak_flops_for("bf16") == PEAK_FLOPS
    assert spec.peak_flops_for("f32") == PEAK_FLOPS
    assert spec.hbm_bandwidth_bytes_per_s == HBM_BW
    assert spec.link_bandwidth_bytes_per_s == LINK_BW
    assert spec.hbm_latency_s == 0.0 and spec.link_latency_s == 0.0
    assert spec.derating == ((0.0, 1.0),)


def test_reference_spec_task_seconds_bitwise_equals_legacy_roofline():
    """The bit-for-bit reduction the whole migration rests on: with zero
    latency and constant derating 1.0, task_seconds == the legacy
    max(flops/peak, bytes/bw) as EXACT floats (0.0 + x == x and
    bw * 1.0 == bw in IEEE arithmetic), across magnitudes."""
    spec = load_device_spec(os.path.join(spec_root(), "tpu-v5e.json"))
    rng = np.random.default_rng(0)
    for _ in range(200):
        flops = float(10.0 ** rng.uniform(6, 16))
        nbytes = float(10.0 ** rng.uniform(3, 12))
        legacy = max(flops / PEAK_FLOPS, nbytes / HBM_BW)
        assert spec.task_seconds(flops, nbytes, "bf16") == legacy
    assert spec.link_seconds(1e9) == 1e9 / LINK_BW


def test_derating_curve_interpolation():
    spec = DeviceSpec.from_json(
        _spec_payload(derating=[[1e3, 0.5], [9e3, 0.9]], hbm_latency_s=0.0)
    )
    assert spec.hbm_efficiency(10.0) == 0.5  # clamped below first knot
    assert spec.hbm_efficiency(1e3) == 0.5
    assert spec.hbm_efficiency(5e3) == pytest.approx(0.7)  # midpoint
    assert spec.hbm_efficiency(1e6) == 0.9  # clamped above last knot
    # derating makes memory-bound tasks slower, never faster
    assert spec.task_seconds(1.0, 1e3, "bf16") == 2 * (1e3 / 1e12)


def test_limit_curve_is_per_stage_capacity():
    spec = DeviceSpec.from_json(_spec_payload())
    assert spec.limit_curve(4) == [1.6e10] * 4


# ---------------------------------------------------------------------------
# workload profiles
# ---------------------------------------------------------------------------

_PINNED = os.path.join(spec_root(), "workloads", "pinned-4stage.json")


def _workload_payload():
    with open(_PINNED) as f:
        return json.load(f)


def test_pinned_workload_loads_and_derives():
    wl = load_workload_profile(_PINNED)
    assert wl.num_stages == 4 and wl.dtype == "bf16"
    spec = load_device_spec(os.path.join(spec_root(), "h100-sxm.json"))
    costs = derive_stage_costs(wl, spec)
    assert costs.num_stages == 4
    for p in TASK_PROGRAMS:
        assert all(t > 0 for t in getattr(costs, f"{p}_time"))
    # B/W split composes exactly, and the saved-residual trade is present:
    # fewer FLOPs than BWD_WEIGHT but more HBM traffic
    for s in range(4):
        assert costs.bwd_time[s] == costs.bwd_input_time[s] + costs.bwd_weight_time[s]
        assert wl.counts[s]["bwd_weight_saved"].flops < wl.counts[s]["bwd_weight"].flops
        assert (
            wl.counts[s]["bwd_weight_saved"].hbm_bytes
            > wl.counts[s]["bwd_weight"].hbm_bytes
        )
    mm = derive_memory_model(wl)
    assert len(mm.stages) == 4 and mm.seq_len == wl.seq_len


@pytest.mark.parametrize(
    "mutate, match",
    [
        (lambda p: p.update(schema_version=7), r"schema_version 7 != supported"),
        (lambda p: p.update(dtype="float16"), r"unknown workload dtype 'float16'"),
        (lambda p: p["stages"][0].pop("bwd_weight_saved"),
         r"stages\[0\].*missing required field 'bwd_weight_saved'"),
        (lambda p: p["stages"][1]["fwd"].update(flops=-1.0),
         r"stages\[1\]\.fwd.*'flops' must be positive"),
        (lambda p: p["stages"][2]["memory"].update(bogus_field=1.0),
         r"stages\[2\]\.memory.*StageMemorySpec fields"),
        (lambda p: p.update(stages=[]), r"'stages' must be a non-empty list"),
    ],
    ids=["schema-version", "non-key-dtype", "missing-program",
         "negative-flops", "unknown-memory-field", "no-stages"],
)
def test_workload_loading_fails_closed(tmp_path, mutate, match):
    payload = _workload_payload()
    mutate(payload)
    path = tmp_path / "wl.json"
    path.write_text(json.dumps(payload))
    with pytest.raises(DeviceSpecError, match=match):
        load_workload_profile(str(path))


# ---------------------------------------------------------------------------
# roofline-constant scan: the tier-1 twin of the CI grep gate
# ---------------------------------------------------------------------------

#: a raw roofline-constant DEFINITION, or the legacy magic numbers spelled
#: inline — either one forks the cost model outside core/devicespec.py
_ROOFLINE_RE = re.compile(
    r"(PEAK_FLOPS|HBM_BW|LINK_BW)\s*=\s*[0-9]|[^0-9_](197e12|819e9|50e9)[^0-9]"
)
_SCAN_ROOTS = ["src/repro", "benchmarks", "examples"]
_SCAN_EXEMPT = {os.path.join("src", "repro", "core", "devicespec.py")}


def test_no_raw_roofline_constants_outside_devicespec():
    """Hardware numbers are data (specs/*.json), not code.  The single
    allowed in-code home is core/devicespec.py's legacy trio; the CI lint
    job runs the same grep for per-PR log visibility."""
    offenders = []
    for base in _SCAN_ROOTS:
        for root, _, files in os.walk(os.path.join(_REPO, base)):
            for f in sorted(files):
                if not f.endswith(".py"):
                    continue
                path = os.path.join(root, f)
                rel = os.path.relpath(path, _REPO)
                if rel in _SCAN_EXEMPT:
                    continue
                with open(path) as fh:
                    for lineno, line in enumerate(fh, 1):
                        if _ROOFLINE_RE.search(line):
                            offenders.append(f"{rel}:{lineno}: {line.strip()}")
    assert not offenders, (
        "raw roofline constants outside core/devicespec.py — author a "
        "specs/*.json device spec instead:\n" + "\n".join(offenders)
    )


# ---------------------------------------------------------------------------
# hardware-matrix conformance (in-process twin of the CI matrix job)
# ---------------------------------------------------------------------------


def test_hardware_matrix_goldens_conformant():
    """Every committed spec's derive -> enumerate -> tune -> simulate slice
    matches its golden fixture — the same check CI runs one matrix cell
    per part, so a local run catches the drift before the push does."""
    if _REPO not in sys.path:
        sys.path.insert(0, _REPO)
    from benchmarks.hardware_matrix import all_spec_paths, check_spec

    paths = all_spec_paths()
    assert len(paths) >= 5
    drifts = [d for p in paths for d in check_spec(p)]
    assert not drifts, "hardware-matrix drift vs specs/golden/:\n" + "\n".join(drifts)


def test_hardware_matrix_divergent_choice():
    """The acceptance criterion: the SAME pinned workload tunes to a
    DIFFERENT ScheduleSpec on the compute-rich H100 vs the memory-starved
    synthetic part — the device spec, not the code path, decides."""
    if _REPO not in sys.path:
        sys.path.insert(0, _REPO)
    from benchmarks.hardware_matrix import conformance_slice

    h100 = conformance_slice(os.path.join(spec_root(), "h100-sxm.json"))
    skew = conformance_slice(os.path.join(spec_root(), "synthetic-extreme-skew.json"))
    assert h100["chosen"] != skew["chosen"]
    # and the skewed part's 6 GB capacity visibly prunes the candidate set
    assert len(skew["candidates"]) < len(h100["candidates"])
