"""Kind-specific semantics of the schedule family.

The structural battery (lowering validity, FIFO links, op conservation,
liveness vs the memory-model prediction, slot exactness) lives in the
differential conformance harness, ``test_family_conformance.py``, which
sweeps every kind through ONE oracle.  This module keeps the claims that
are *about a particular kind*: degenerate aliases, zero-bubble memory
guarantees and byte pricing, H2's warmup semantics, divisibility guards,
and that the simulator executes every member.
"""

import numpy as np
import pytest

from repro.core import (
    ScheduleSpec,
    SearchSpace,
    StableTrace,
    StageCosts,
    simulate_plan,
    uniform_network,
)
from repro.core.schedule import (
    gpipe_order,
    kfkb_order,
    lower_to_table,
    make_plan,
    one_f_one_b_order,
    peak_live_activations,
    tick_table,
    tick_table_stats,
    zb_h1_order,
    zb_orders,
)

FAMILY = [
    ("kfkb", 1, 1, 0),
    ("kfkb", 2, 1, 0),
    ("kfkb", 8, 1, 0),  # == GPipe at M=8
    ("zb_h1", 1, 1, 0),
    ("zb_h1", 2, 1, 0),
    ("zb_h2", 1, 1, 1),
    ("zb_h2", 2, 1, 2),
    ("interleaved", 1, 2, 0),
    ("interleaved", 2, 2, 0),
    ("interleaved_zb", 1, 2, 0),
    ("interleaved_zb", 2, 2, 0),
]


def _plans(S=4, M=8):
    return [
        make_plan(S, M, spec=ScheduleSpec(kind=kind, k=k, num_virtual=v, extra_warmup=w))
        for kind, k, v, w in FAMILY
    ]


def test_degenerate_k_cases():
    """k == 1 is exactly 1F1B and k == M exactly GPipe, for the base kind and
    through make_plan's aliases."""
    S, M = 4, 8
    for s in range(S):
        assert kfkb_order(S, M, 1, s) == one_f_one_b_order(S, M, s)
        assert kfkb_order(S, M, M, s) == gpipe_order(S, M, s)
    alias_1f1b = make_plan(S, M, spec=ScheduleSpec(kind="1f1b", k=3))
    assert alias_1f1b.k == 1 and alias_1f1b.kind == "kfkb"
    alias_gpipe = make_plan(S, M, spec=ScheduleSpec(kind="gpipe"))
    assert alias_gpipe.k == M


def test_zb_h1_memory_equals_1f1b():
    """The "H1" guarantee: peak live activations (slot needs) match the
    equal-k kFkB plan per stage — zero-bubble is free memory-wise."""
    for S, M in [(2, 4), (4, 8), (4, 16), (8, 16)]:
        for k in (1, 2):
            zb = peak_live_activations(make_plan(S, M, spec=ScheduleSpec(kind="zb_h1", k=k)))
            base = peak_live_activations(make_plan(S, M, k))
            assert zb == base, (S, M, k, zb, base)


def test_zb_h2_buys_exactly_w_slots_per_stage():
    """The "H2" trade: every extra warmup unit costs one live slot per stage
    (per group member), clamped where the group count leaves no room."""
    S, M = 4, 16
    base = peak_live_activations(make_plan(S, M, spec=ScheduleSpec(kind="zb_h1")))
    for w in (1, 2, 3):
        h2 = peak_live_activations(make_plan(S, M, spec=ScheduleSpec(kind="zb_h2", extra_warmup=w)))
        assert h2 == [min(p + w, M) for p in base], (w, h2, base)


def test_zb_vector_warmup_uniform_equals_scalar():
    """A uniform vector w[s] = (w, w, ..., w) IS the scalar-w H2 — same
    orders, same name, same peaks."""
    S, M = 4, 16
    for w in (1, 2):
        scalar = make_plan(S, M, spec=ScheduleSpec(kind="zb_h2", extra_warmup=w))
        vector = make_plan(S, M, spec=ScheduleSpec(kind="zb_h2", extra_warmup=(w,) * S))
        assert scalar.name == vector.name
        assert [t.key() for o in scalar.orders for t in o] == [
            t.key() for o in vector.orders for t in o
        ]


def test_zb_vector_warmup_per_stage_memory_price():
    """Each stage pays for ITS OWN w[s] only: peaks sit between H1's and
    H1 + w[s], and a stage with w[s] = 0 keeps exactly its H1 peak when its
    upstream stages can feed the difference."""
    S, M = 4, 16
    h1 = peak_live_activations(make_plan(S, M, spec=ScheduleSpec(kind="zb_h1")))
    w = (2, 0, 1, 0)
    peaks = peak_live_activations(make_plan(S, M, spec=ScheduleSpec(kind="zb_h2", extra_warmup=w)))
    assert all(h1[s] <= peaks[s] <= h1[s] + w[s] for s in range(S)), (h1, peaks)
    # stage 0 has no upstream: its extra warmup depth is realized exactly
    assert peaks[0] == h1[0] + w[0]


def test_zb_vector_warmup_length_and_guards():
    """The vector must be one entry per stage, >= 0, with some stage >= 1."""
    with pytest.raises(ValueError, match="one entry per stage"):
        make_plan(4, 8, spec=ScheduleSpec(kind="zb_h2", extra_warmup=(1, 2)))
    with pytest.raises(ValueError, match=">= 0"):
        make_plan(4, 8, spec=ScheduleSpec(kind="zb_h2", extra_warmup=(1, -1, 0, 0)))
    with pytest.raises(ValueError, match="extra_warmup >= 1"):
        make_plan(4, 8, spec=ScheduleSpec(kind="zb_h2", extra_warmup=(0, 0, 0, 0)))


def test_interleaved_zb_composes_with_warmup():
    """The "interleaved H2": extra_warmup raises the per-device cap above
    the plain interleaved peak — more live slots bought at exactly the
    stages that asked, never beyond plain + w[s]."""
    S, M, v = 4, 8, 2
    plain = peak_live_activations(make_plan(S, M, spec=ScheduleSpec(kind="interleaved", num_virtual=v)))
    w = (2, 1, 0, 2)
    plan = make_plan(S, M, spec=ScheduleSpec(kind="interleaved_zb", num_virtual=v, extra_warmup=w))
    peaks = peak_live_activations(plan)
    zb0 = peak_live_activations(make_plan(S, M, spec=ScheduleSpec(kind="interleaved_zb", num_virtual=v)))
    assert all(peaks[s] <= plain[s] + w[s] for s in range(S)), (peaks, plain)
    assert all(peaks[s] >= zb0[s] for s in range(S))
    assert any(peaks[s] > zb0[s] for s in range(S) if w[s] > 0)  # warmup realized


def test_zb_orders_w0_is_h1():
    """The cap-parameterized builder at w=0 IS the H1 schedule."""
    S, M = 4, 8
    assert zb_orders(S, M, 1, extra_warmup=0) == zb_orders(S, M, 1)
    plan = make_plan(S, M, spec=ScheduleSpec(kind="zb_h1"))
    for s in range(S):
        assert [(t.op, t.mb) for t in plan.orders[s]] == zb_h1_order(S, M, s)


def test_extra_warmup_guards():
    """extra_warmup is a zb_h2-only axis, and zb_h2 requires it >= 1 (w == 0
    is exactly zb_h1 and must be spelled that way)."""
    with pytest.raises(ValueError, match="extra_warmup >= 1"):
        make_plan(4, 8, spec=ScheduleSpec(kind="zb_h2"))
    with pytest.raises(ValueError, match="warmup-capable kind"):
        make_plan(4, 8, spec=ScheduleSpec(kind="zb_h1", extra_warmup=1))
    with pytest.raises(ValueError):
        make_plan(4, 8, spec=ScheduleSpec(kind="zb_h2", extra_warmup=-1))


def test_interleaved_divisibility_guard():
    with pytest.raises(ValueError):
        make_plan(4, 6, spec=ScheduleSpec(kind="interleaved", num_virtual=2))  # G=6, S=4
    with pytest.raises(ValueError):
        make_plan(4, 8, spec=ScheduleSpec(kind="interleaved", k=3, num_virtual=2))  # k does not divide M
    with pytest.raises(ValueError):
        make_plan(4, 8, spec=ScheduleSpec(kind="kfkb", num_virtual=2))  # chunks need interleaved
    with pytest.raises(ValueError):
        make_plan(4, 6, spec=ScheduleSpec(kind="interleaved_zb", num_virtual=2))  # same rule


def test_interleaved_shrinks_fill_drain_bubble():
    """The point of virtual stages: on the unit-cost tick grid the bubble
    fraction strictly drops going 1F1B -> interleaved (same device count)."""
    S, M = 4, 8
    base = tick_table_stats(tick_table(make_plan(S, M, 1)))
    inter = make_plan(S, M, spec=ScheduleSpec(kind="interleaved", num_virtual=2)).lower().stats()
    assert inter["bubble_fraction"] < base["bubble_fraction"]


def test_interleaved_zb_memory_never_exceeds_plain_interleaved():
    """The joint builder's guarantee: the B/W split fills bubbles without
    buying any extra live slots over the equal-(k, v) interleaved plan."""
    for S, M, k, v in [(4, 8, 1, 2), (4, 8, 2, 2), (2, 8, 2, 2), (4, 16, 2, 2)]:
        zb = peak_live_activations(
            make_plan(S, M, spec=ScheduleSpec(kind="interleaved_zb", k=k, num_virtual=v))
        )
        plain = peak_live_activations(
            make_plan(S, M, spec=ScheduleSpec(kind="interleaved", k=k, num_virtual=v))
        )
        assert all(a <= b for a, b in zip(zb, plain)), (S, M, k, v, zb, plain)


def test_legacy_tick_table_shim_matches_grid():
    plan = make_plan(4, 8, 2)
    legacy = tick_table(plan)
    grid = lower_to_table(plan).grid
    assert legacy.shape == (4, grid.shape[1], 3)
    np.testing.assert_array_equal(legacy, grid[:, :, [0, 1, 3]])


def test_plan_lowering_is_cached():
    """Plans are static: ``plan.lower()`` computes the TabularPlan once and
    returns the same object forever after (the tuner/engine contract)."""
    plan = make_plan(4, 8, spec=ScheduleSpec(kind="zb_h1", k=2))
    assert plan.lower() is plan.lower()
    # the uncached entry point still rebuilds (used by the shim tests above)
    assert lower_to_table(plan) is not plan.lower()


def test_simulator_runs_every_family_member():
    S, M = 4, 8
    costs = StageCosts.uniform(S, 1.0, act_bytes=1.0)
    net = uniform_network(S, lambda: StableTrace(10.0))
    for plan in _plans(S, M):
        res = simulate_plan(plan, costs, net)
        # conservation: every device executed all of its tasks
        assert len(res.task_finish) == sum(len(o) for o in plan.orders)
        assert res.pipeline_length > 0


def test_enumerate_rejects_unknown_kind():
    """A typo'd kind must fail loudly, not silently drop the whole family."""
    from repro.core import MemoryModel, enumerate_candidates

    mm = MemoryModel.uniform(
        num_stages=4, seq_len=64, param_bytes=1e6, optimizer_bytes=2e6,
        grad_bytes=1e6, stage_input_bytes_per_token=512.0,
        layer_act_bytes_per_token=64.0, num_layers_per_stage=2,
    )
    with pytest.raises(ValueError, match="unknown schedule kind"):
        enumerate_candidates(
        4, 32, mm, 1e8,
        space=SearchSpace(kinds=("kfkb", "zb-h1"), max_k=2),
    )


@pytest.mark.parametrize("kind,w", [("zb_h1", 0), ("zb_h2", 1), ("zb_h2", 2)])
def test_zb_memory_model_prices_the_dy_context(kind, w):
    """Zero-bubble kinds match kFkB in peak *slots* (plus w for H2) but must
    cost MORE in bytes: the engine stashes a hidden-sized dy next to each
    saved stage input."""
    from repro.core import MemoryModel

    mm = MemoryModel.uniform(
        num_stages=4, seq_len=64, param_bytes=1e6, optimizer_bytes=2e6,
        grad_bytes=1e6, stage_input_bytes_per_token=512.0,
        layer_act_bytes_per_token=64.0, num_layers_per_stage=2,
    )
    base = make_plan(4, 8, 2, micro_batch_size=4)
    zb = make_plan(4, 8, spec=ScheduleSpec(kind=kind, k=2, extra_warmup=w, micro_batch_size=4))
    expected = [min(p + w * 2, 8) for p in peak_live_activations(base)]
    assert peak_live_activations(zb) == expected
    assert mm.peak_bytes(zb) > mm.peak_bytes(base)


def test_h2_peak_bytes_monotone_in_w():
    """The binary search in enumerate_candidates relies on this."""
    from repro.core import MemoryModel

    mm = MemoryModel.uniform(
        num_stages=4, seq_len=64, param_bytes=1e6, optimizer_bytes=2e6,
        grad_bytes=1e6, stage_input_bytes_per_token=512.0,
        layer_act_bytes_per_token=64.0, num_layers_per_stage=2,
    )
    peaks = [
        mm.peak_bytes(make_plan(4, 16, spec=ScheduleSpec(kind="zb_h2", extra_warmup=w, micro_batch_size=2)))
        for w in (1, 2, 3)
    ]
    assert peaks == sorted(peaks) and peaks[0] < peaks[-1]
