"""Schedule-family invariants: zero-bubble + interleaved over the tabular plan.

Deterministic (no hypothesis): these guard the heart of the reproduction —
every plan builder lowers to a dependency-valid TabularPlan with exact
send/recv edges, the zero-bubble plan really removes bubbles without
costing activation slots, and the grouped hybrids compose.
"""

import numpy as np
import pytest

from repro.core import (
    StableTrace,
    StageCosts,
    simulate_plan,
    uniform_network,
)
from repro.core.schedule import (
    Op,
    gpipe_order,
    kfkb_order,
    lower_to_table,
    make_plan,
    one_f_one_b_order,
    peak_live_activations,
    tick_table,
    tick_table_stats,
    zb_h1_order,
)

FAMILY = [
    ("kfkb", 1, 1),
    ("kfkb", 2, 1),
    ("kfkb", 8, 1),  # == GPipe at M=8
    ("zb_h1", 1, 1),
    ("zb_h1", 2, 1),
    ("interleaved", 1, 2),
    ("interleaved", 2, 2),
]


def _plans(S=4, M=8):
    return [
        make_plan(S, M, k, kind=kind, num_virtual=v) for kind, k, v in FAMILY
    ]


def test_every_builder_lowers_to_valid_tabular_plan():
    """Acceptance: all plan builders (1F1B, GPipe, kFkB, ZB-H1, interleaved)
    lower to TabularPlan, and the lowering satisfies the dependency-validity
    and FIFO invariants (every recv preceded by its matching send)."""
    for plan in _plans():
        table = plan.lower()
        table.validate()
        # every non-idle cell appears once per task of the plan
        busy = int((table.grid[:, :, 0] != int(Op.IDLE)).sum())
        assert busy == sum(len(o) for o in plan.orders)


def test_edges_cover_exactly_the_cross_device_transfers():
    S, M = 4, 8
    for plan in _plans(S, M):
        table = plan.lower()
        V = plan.total_virtual_stages
        n_fwd = sum(1 for t in plan.tasks() if t.op == Op.FWD) - M  # vstage 0 local
        n_bwd = M * (V - 1)  # every non-last virtual stage's B receives
        fwd_edges = [e for e in table.edges if e.is_forward]
        bwd_edges = [e for e in table.edges if not e.is_forward]
        assert len(fwd_edges) == n_fwd == M * (V - 1)
        assert len(bwd_edges) == n_bwd
        for e in table.edges:
            assert e.send_tick < e.recv_tick


def test_degenerate_k_cases():
    """k == 1 is exactly 1F1B and k == M exactly GPipe, for the base kind and
    through make_plan's aliases."""
    S, M = 4, 8
    for s in range(S):
        assert kfkb_order(S, M, 1, s) == one_f_one_b_order(S, M, s)
        assert kfkb_order(S, M, M, s) == gpipe_order(S, M, s)
    alias_1f1b = make_plan(S, M, 3, kind="1f1b")
    assert alias_1f1b.k == 1 and alias_1f1b.kind == "kfkb"
    alias_gpipe = make_plan(S, M, 1, kind="gpipe")
    assert alias_gpipe.k == M


def test_zb_streams_are_fifo_and_complete():
    """Per-stage F, B, W streams of ZB-H1 each run every micro-batch exactly
    once in FIFO order, W strictly after its B, B strictly after its F."""
    S, M = 4, 8
    for k in (1, 2, 4, M):
        plan = make_plan(S, M, k, kind="zb_h1")
        for order in plan.orders:
            pos = {}
            for i, t in enumerate(order):
                pos[(int(t.op), t.mb)] = i
            for op in (Op.FWD, Op.BWD_INPUT, Op.BWD_WEIGHT):
                mbs = [t.mb for t in order if t.op == op]
                assert mbs == sorted(mbs), f"{op} stream not FIFO"
                assert set(mbs) == set(range(M))
            for mb in range(M):
                assert pos[(int(Op.FWD), mb)] < pos[(int(Op.BWD_INPUT), mb)]
                assert pos[(int(Op.BWD_INPUT), mb)] < pos[(int(Op.BWD_WEIGHT), mb)]


def test_zb_h1_memory_equals_1f1b():
    """The "H1" guarantee: peak live activations (slot needs) match the
    equal-k kFkB plan per stage — zero-bubble is free memory-wise."""
    for S, M in [(2, 4), (4, 8), (4, 16), (8, 16)]:
        for k in (1, 2):
            zb = peak_live_activations(make_plan(S, M, k, kind="zb_h1"))
            base = peak_live_activations(make_plan(S, M, k))
            assert zb == base, (S, M, k, zb, base)


def test_zb_h1_order_per_stage_helper():
    S, M = 4, 8
    plan = make_plan(S, M, 1, kind="zb_h1")
    for s in range(S):
        assert [(t.op, t.mb) for t in plan.orders[s]] == zb_h1_order(S, M, s)


def test_interleaved_divisibility_guard():
    with pytest.raises(ValueError):
        make_plan(4, 6, 1, kind="interleaved", num_virtual=2)  # G=6, S=4
    with pytest.raises(ValueError):
        make_plan(4, 8, 3, kind="interleaved", num_virtual=2)  # k does not divide M
    with pytest.raises(ValueError):
        make_plan(4, 8, 1, kind="kfkb", num_virtual=2)  # chunks need interleaved


def test_interleaved_chunks_cover_all_microbatches():
    S, M, v = 4, 8, 2
    for k in (1, 2):
        plan = make_plan(S, M, k, kind="interleaved", num_virtual=v)
        for order in plan.orders:
            for c in range(v):
                for op in (Op.FWD, Op.BWD):
                    mbs = [t.mb for t in order if t.op == op and t.chunk == c]
                    assert mbs == sorted(mbs)
                    assert set(mbs) == set(range(M))


def test_interleaved_shrinks_fill_drain_bubble():
    """The point of virtual stages: on the unit-cost tick grid the bubble
    fraction strictly drops going 1F1B -> interleaved (same device count)."""
    S, M = 4, 8
    base = tick_table_stats(tick_table(make_plan(S, M, 1)))
    inter = make_plan(S, M, 1, kind="interleaved", num_virtual=2).lower().stats()
    assert inter["bubble_fraction"] < base["bubble_fraction"]


def test_slot_liveness_family():
    """Slots are liveness-exact for every family member: the number of
    distinct slots per device equals its peak live count, with no gaps."""
    for plan in _plans():
        peaks = peak_live_activations(plan)
        for s, order in enumerate(plan.orders):
            slots_used = {t.slot for t in order if t.op == Op.FWD}
            assert slots_used == set(range(peaks[s]))


def test_legacy_tick_table_shim_matches_grid():
    plan = make_plan(4, 8, 2)
    legacy = tick_table(plan)
    grid = lower_to_table(plan).grid
    assert legacy.shape == (4, grid.shape[1], 3)
    np.testing.assert_array_equal(legacy, grid[:, :, [0, 1, 3]])


def test_simulator_runs_every_family_member():
    S, M = 4, 8
    costs = StageCosts.uniform(S, 1.0, act_bytes=1.0)
    net = uniform_network(S, lambda: StableTrace(10.0))
    for plan in _plans(S, M):
        res = simulate_plan(plan, costs, net)
        # conservation: every device executed all of its tasks
        assert len(res.task_finish) == sum(len(o) for o in plan.orders)
        assert res.pipeline_length > 0


def test_enumerate_rejects_unknown_kind():
    """A typo'd kind must fail loudly, not silently drop the whole family."""
    from repro.core import MemoryModel, enumerate_candidates

    mm = MemoryModel.uniform(
        num_stages=4, seq_len=64, param_bytes=1e6, optimizer_bytes=2e6,
        grad_bytes=1e6, stage_input_bytes_per_token=512.0,
        layer_act_bytes_per_token=64.0, num_layers_per_stage=2,
    )
    with pytest.raises(ValueError, match="unknown schedule kind"):
        enumerate_candidates(4, 32, mm, 1e8, max_k=2, kinds=("kfkb", "zb-h1"))


def test_zb_memory_model_prices_the_dy_context():
    """ZB-H1 matches kFkB in peak *slots* but must cost MORE in bytes: the
    engine stashes a hidden-sized dy next to each saved stage input."""
    from repro.core import MemoryModel

    mm = MemoryModel.uniform(
        num_stages=4, seq_len=64, param_bytes=1e6, optimizer_bytes=2e6,
        grad_bytes=1e6, stage_input_bytes_per_token=512.0,
        layer_act_bytes_per_token=64.0, num_layers_per_stage=2,
    )
    base = make_plan(4, 8, 2, micro_batch_size=4)
    zb = make_plan(4, 8, 2, micro_batch_size=4, kind="zb_h1")
    assert peak_live_activations(zb) == peak_live_activations(base)
    assert mm.peak_bytes(zb) > mm.peak_bytes(base)
