"""Property-based tests of the kFkB schedule layer (the paper's core)."""

import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis optional: property tests skip cleanly

from repro.core.schedule import (
    Op,
    gpipe_order,
    kfkb_order,
    make_plan,
    one_f_one_b_order,
    peak_live_activations,
    tick_table,
    tick_table_stats,
)


def _sm_k():
    """(num_stages, num_microbatches, k) with k | M and M >= S."""
    return st.tuples(
        st.integers(1, 8), st.integers(1, 6), st.integers(1, 4)
    ).map(lambda t: (t[0], t[0] * t[1] * t[2], t[2]))


@given(_sm_k())
@settings(max_examples=60, deadline=None)
def test_plan_validates(smk):
    S, M, k = smk
    plan = make_plan(S, M, k)
    plan.validate()  # every FWD/BWD exactly once, BWD after FWD


@given(_sm_k())
@settings(max_examples=60, deadline=None)
def test_kfkb_group_contiguity(smk):
    """Members of one k-group appear contiguously and in FIFO order."""
    S, M, k = smk
    for s in range(S):
        order = kfkb_order(S, M, k, s)
        for op in (Op.FWD, Op.BWD):
            mbs = [mb for o, mb in order if o == op]
            assert mbs == sorted(mbs) or k == 1 or True  # FIFO within groups:
            for g in range(M // k):
                chunk = mbs[g * k : (g + 1) * k]
                assert chunk == list(range(chunk[0], chunk[0] + k))


def test_k1_is_1f1b_and_kM_is_gpipe():
    S, M = 4, 8
    for s in range(S):
        assert kfkb_order(S, M, 1, s) == one_f_one_b_order(S, M, s)
        assert kfkb_order(S, M, M, s) == gpipe_order(S, M, s)


@given(_sm_k())
@settings(max_examples=40, deadline=None)
def test_peak_activations_bounds(smk):
    """Paper §4.1: peak live activations grow with k, bounded by M, and the
    last stage of 1F1B keeps exactly 1."""
    S, M, k = smk
    peaks_k = peak_live_activations(make_plan(S, M, k))
    peaks_1 = peak_live_activations(make_plan(S, M, 1))
    assert all(1 <= p <= M for p in peaks_k)
    assert all(pk >= p1 for pk, p1 in zip(peaks_k, peaks_1))
    assert peaks_1[-1] == 1  # early backward at the last stage
    peaks_M = peak_live_activations(make_plan(S, M, M))
    assert all(p == M for p in peaks_M)  # GPipe keeps everything


@given(_sm_k())
@settings(max_examples=40, deadline=None)
def test_1f1b_peak_is_depth_bounded(smk):
    """DAPPLE's result: 1F1B peak at stage s is min(S - s, M)."""
    S, M, _ = smk
    peaks = peak_live_activations(make_plan(S, M, 1))
    assert peaks == [min(S - s, M) for s in range(S)]


@given(_sm_k())
@settings(max_examples=40, deadline=None)
def test_slot_assignment_is_liveness_exact(smk):
    S, M, k = smk
    plan = make_plan(S, M, k)
    peaks = peak_live_activations(plan)
    for s, order in enumerate(plan.orders):
        slots_used = {t.slot for t in order if t.op == Op.FWD}
        assert len(slots_used) == peaks[s]  # no wasted buffers
        assert slots_used == set(range(peaks[s]))


@given(_sm_k())
@settings(max_examples=30, deadline=None)
def test_tick_table_respects_dependencies(smk):
    S, M, k = smk
    plan = make_plan(S, M, k)
    table = tick_table(plan)
    done = {}
    for t in range(table.shape[1]):
        for s in range(S):
            op, mb, _ = (int(v) for v in table[s, t])
            if op == int(Op.IDLE):
                continue
            if op == int(Op.FWD) and s > 0:
                assert done[(int(Op.FWD), s - 1, mb)] < t
            if op == int(Op.BWD):
                assert done[(int(Op.FWD), s, mb)] < t
                if s < S - 1:
                    assert done[(int(Op.BWD), s + 1, mb)] < t
            done[(op, s, mb)] = t
    assert len(done) == 2 * S * M  # everything executed


def test_tick_table_1f1b_bubble_fraction():
    """Unit-cost 1F1B: busy = 2M per stage, length = 2(M + S - 1) ticks."""
    S, M = 4, 8
    stats = tick_table_stats(tick_table(make_plan(S, M, 1)))
    assert stats["busy"] == 2 * S * M
    assert stats["ticks"] == 2 * (M + S - 1)


@given(_sm_k())
@settings(max_examples=20, deadline=None)
def test_tick_table_length_lower_bound(smk):
    S, M, k = smk
    stats = tick_table_stats(tick_table(make_plan(S, M, k)))
    assert stats["ticks"] >= 2 * M  # a stage must run 2M tasks serially
    assert stats["ticks"] >= 2 * M + 2 * (S - 1)  # plus fill/drain
