"""The unified observability layer (``repro.obs``): trace, metrics, flight,
drift — and its instrumentation contract with the runtime/tuner/fabric.

Six suites:

* :class:`TraceRecorder` — span/instant/counter recording, first-use track
  order, Chrome trace-event schema round-trip, nesting/overlap validators,
  and the headline determinism property: under an injected tick clock two
  recordings of the same event sequence export **byte-identical** JSON;
* :func:`render_simulated_trace` — the PLAN_KINDS gate: every registered
  schedule kind's simulated timeline must render with pairwise-disjoint
  spans on every device and link track (an overlap is a renderer or
  simulator bug), plus the committed golden fixture staying bit-for-bit
  reproducible (CI's lint job re-validates the fixture's schema);
* :class:`MetricsRegistry` — counter/gauge/histogram semantics, labeled
  series, one-name-one-kind, deterministic ``snapshot``/``delta``;
* :class:`FlightRecorder` — ring bound + drop accounting, monotonic ``seq``,
  kind filters, deterministic dumps, never-raising ``auto_dump``;
* :class:`DriftMonitor` + ``TelemetryBus`` self-reporting + the de-flaked
  ``warm_switch_frac_from_trace`` bench definition;
* integration — ``CoordinatorServer.fabric_metrics()``'s frozen dict shape
  over the registry, ``TuningRecord`` back-compat, and a scripted two-host
  fleet whose shared trace carries the acceptance contract: both hosts'
  iteration spans, the tuner's per-candidate decision trail, and a full
  PREPARE -> COMMIT barrier epoch.
"""

import dataclasses
import json
import os
from types import SimpleNamespace

import pytest

from repro.obs import Observability
from repro.obs.drift import DriftMonitor
from repro.obs.flight_recorder import FlightRecorder
from repro.obs.metrics import HistogramValue, MetricsRegistry
from repro.obs.trace import (
    TraceRecorder,
    TraceValidationError,
    merge_traces,
    render_simulated_trace,
    spans_by_track,
    validate_chrome_trace,
    validate_no_overlap,
)

GOLDEN = os.path.join(os.path.dirname(__file__), "golden")


class Tick:
    """Deterministic injected clock: each reading advances by ``step``."""

    def __init__(self, step=0.001):
        self.t = 0.0
        self.step = step

    def __call__(self):
        self.t += self.step
        return self.t


# ---------------------------------------------------------------------------
# TraceRecorder
# ---------------------------------------------------------------------------


def _record_sample(rec: TraceRecorder) -> None:
    with rec.span("host0/iterations", "iter 0", plan="p"):
        rec.instant("host0/fabric", "PREPARE epoch 1", spec="s")
    sp = rec.span("host0/switches", "switch q", warm=True)
    rec.end_span(sp, restacked=False)
    rec.counter("host0/fabric", "windows", 3)
    rec.add_span("predicted/stage0", "F mb0", 0.5, 1.0, op="F")
    rec.add_instant("coordinator/tuner", "decision q", 2.5, chosen="q")


def test_recorder_chrome_export_round_trip():
    rec = TraceRecorder(clock=Tick())
    _record_sample(rec)
    payload = rec.to_chrome_trace()
    validate_chrome_trace(payload)
    # one process row per track segment, one thread lane per track
    procs = {e["args"]["name"] for e in payload["traceEvents"]
             if e["ph"] == "M" and e["name"] == "process_name"}
    assert procs == {"host0", "predicted", "coordinator"}
    tracks = {e["args"]["name"] for e in payload["traceEvents"]
              if e["ph"] == "M" and e["name"] == "thread_name"}
    assert tracks == {"host0/iterations", "host0/fabric", "host0/switches",
                      "predicted/stage0", "coordinator/tuner"}
    # the span args survive; instants carry scope "t"
    spans = spans_by_track(payload)
    assert spans["host0/iterations"][0]["args"] == {"plan": "p"}
    assert spans["host0/switches"][0]["args"] == {"warm": True, "restacked": False}
    instants = [e for e in payload["traceEvents"] if e["ph"] == "i"]
    assert all(e["s"] == "t" for e in instants)
    # explicit-timestamp events land at their stated times (microseconds)
    assert spans["predicted/stage0"][0]["ts"] == pytest.approx(0.5e6)
    assert spans["predicted/stage0"][0]["dur"] == pytest.approx(1.0e6)


def test_export_byte_identical_under_tick_clock():
    a, b = TraceRecorder(clock=Tick()), TraceRecorder(clock=Tick())
    _record_sample(a)
    _record_sample(b)
    assert a.to_json() == b.to_json()
    # and export is idempotent (formatting never mutates state)
    assert a.to_json() == a.to_json()


def test_track_ids_assigned_in_first_use_order():
    rec = TraceRecorder(clock=Tick())
    rec.instant("b/x", "1")
    rec.instant("a/y", "2")
    rec.instant("b/x", "3")
    payload = rec.to_chrome_trace()
    meta = [(e["args"]["name"], e["tid"]) for e in payload["traceEvents"]
            if e["ph"] == "M" and e["name"] == "thread_name"]
    assert meta == [("b/x", 1), ("a/y", 2)]  # first use wins, stable


def test_nested_spans_validate_partial_overlap_rejected():
    rec = TraceRecorder(clock=Tick())
    rec.add_span("t/a", "outer", 0.0, 10.0)
    rec.add_span("t/a", "inner", 2.0, 3.0)
    rec.add_span("t/a", "after", 10.0, 1.0)
    validate_chrome_trace(rec.to_chrome_trace())  # nested + adjacent: fine

    bad = TraceRecorder(clock=Tick())
    bad.add_span("t/a", "one", 0.0, 10.0)
    bad.add_span("t/a", "straddle", 5.0, 10.0)
    with pytest.raises(TraceValidationError, match="partially overlaps"):
        validate_chrome_trace(bad.to_chrome_trace())


def test_validate_no_overlap_is_stricter_and_prefix_scoped():
    rec = TraceRecorder(clock=Tick())
    rec.add_span("predicted/stage0", "outer", 0.0, 10.0)
    rec.add_span("predicted/stage0", "inner", 2.0, 3.0)  # nested: schema-legal
    payload = rec.to_chrome_trace()
    validate_chrome_trace(payload)
    with pytest.raises(TraceValidationError, match="overlaps"):
        validate_no_overlap(payload, "predicted/")
    validate_no_overlap(payload, "host")  # out-of-prefix tracks not checked


def test_validate_schema_rejects_malformed_events():
    with pytest.raises(TraceValidationError, match="traceEvents"):
        validate_chrome_trace({"events": []})
    with pytest.raises(TraceValidationError, match="missing 'ts'"):
        validate_chrome_trace(
            {"traceEvents": [{"ph": "i", "name": "x", "pid": 1, "tid": 1}]}
        )
    with pytest.raises(TraceValidationError, match="non-negative 'dur'"):
        validate_chrome_trace(
            {"traceEvents": [
                {"ph": "X", "name": "x", "pid": 1, "tid": 1, "ts": 0, "dur": -1}
            ]}
        )


def test_merge_traces_keeps_every_lane_disjoint():
    payloads = []
    for host in ("host0", "host1"):
        rec = TraceRecorder(clock=Tick())
        with rec.span(f"{host}/iterations", "iter 0"):
            pass
        payloads.append(rec.to_chrome_trace())
    merged = merge_traces(payloads)
    validate_chrome_trace(merged)
    lanes = [(e["pid"], e["tid"]) for e in merged["traceEvents"]
             if e["ph"] == "M" and e["name"] == "thread_name"]
    assert len(lanes) == len(set(lanes)) == 2
    assert set(spans_by_track(merged)) == {"host0/iterations", "host1/iterations"}


def test_save_writes_loadable_json(tmp_path):
    rec = TraceRecorder(clock=Tick())
    _record_sample(rec)
    path = tmp_path / "trace.json"
    rec.save(str(path))
    validate_chrome_trace(json.loads(path.read_text()))


# ---------------------------------------------------------------------------
# render_simulated_trace: the PLAN_KINDS no-overlap gate + golden fixture
# ---------------------------------------------------------------------------


def _spec_for(kind: str):
    from repro.core.kinds import ScheduleSpec, get_kind

    ks = get_kind(kind)
    return ScheduleSpec(
        kind=kind,
        num_virtual=2 if ks.supports_virtual else 1,
        extra_warmup=1 if ks.requires_warmup else 0,
    )


def test_every_plan_kind_renders_without_overlap():
    """Tier-1 gate: each registered kind's simulated timeline must be a
    legal schedule rendering — pairwise-disjoint spans on every device and
    link track, and the last span ending exactly at the simulated makespan."""
    from repro.core import PLAN_KINDS, StableTrace, StageCosts, make_plan, uniform_network

    S, M = 4, 8
    costs = StageCosts.uniform(S, 1.0, act_bytes=1.0)
    for kind in PLAN_KINDS:
        plan = make_plan(S, M, spec=_spec_for(kind))
        rec, result = render_simulated_trace(
            plan, costs, uniform_network(S, lambda: StableTrace(2.0))
        )
        payload = rec.to_chrome_trace()
        validate_chrome_trace(payload)
        validate_no_overlap(payload, "predicted/")
        spans = [e for evs in spans_by_track(payload).values() for e in evs]
        assert spans, kind
        last_end = max(e["ts"] + e["dur"] for e in spans)
        assert last_end == pytest.approx(result.pipeline_length * 1e6), kind


def test_golden_fixture_bit_for_bit_reproducible():
    """The committed fixture (CI lint re-validates its schema via
    ``python -m repro.obs.trace --validate``) must stay exactly what
    rendering produces — explicit-timestamp rendering touches no clock,
    so the export is deterministic down to the byte."""
    from repro.core import StableTrace, StageCosts, make_plan, uniform_network
    from repro.core.kinds import ScheduleSpec

    S, M = 4, 4
    rec, _ = render_simulated_trace(
        make_plan(S, M, spec=ScheduleSpec(kind="zb_h1")),
        StageCosts.uniform(S, 1.0, act_bytes=1.0),
        uniform_network(S, lambda: StableTrace(2.0)),
    )
    with open(os.path.join(GOLDEN, "predicted_zb_h1_trace.json")) as f:
        committed = f.read()
    assert rec.to_json() + "\n" == committed


def test_render_into_existing_recorder_alongside_observed_tracks():
    from repro.core import StableTrace, StageCosts, make_plan, uniform_network

    rec = TraceRecorder(clock=Tick())
    with rec.span("host0/iterations", "iter 0"):
        pass
    out, _ = render_simulated_trace(
        make_plan(2, 4, 1), StageCosts.uniform(2, 1.0, act_bytes=1.0),
        uniform_network(2, lambda: StableTrace(2.0)), recorder=rec,
    )
    assert out is rec
    tracks = set(spans_by_track(rec.to_chrome_trace()))
    assert "host0/iterations" in tracks and "predicted/stage0" in tracks


# ---------------------------------------------------------------------------
# MetricsRegistry
# ---------------------------------------------------------------------------


def test_counter_gauge_histogram_semantics():
    reg = MetricsRegistry()
    c = reg.counter("requests_total")
    c.inc()
    c.inc(2, host="a")
    assert c.value() == 1 and c.value(host="a") == 2
    with pytest.raises(ValueError, match="cannot decrease"):
        c.inc(-1)

    g = reg.gauge("windows")
    g.set(5)
    g.inc()
    g.dec(2)
    assert g.value() == 4

    h = reg.histogram("latency_seconds")
    for v in (1.0, 3.0, 2.0):
        h.observe(v)
    hv = h.value()
    assert isinstance(hv, HistogramValue)
    assert (hv.count, hv.sum, hv.min, hv.max) == (3, 6.0, 1.0, 3.0)
    assert hv.mean == pytest.approx(2.0)
    assert h.value(host="missing").count == 0  # absent series reads empty


def test_one_name_one_kind():
    reg = MetricsRegistry()
    reg.counter("x")
    reg.counter("x")  # idempotent
    with pytest.raises(ValueError, match="already registered as counter"):
        reg.gauge("x")


def test_snapshot_flat_deterministic_and_delta():
    reg = MetricsRegistry()
    reg.counter("c").inc(3, host="a")
    reg.gauge("g").set(7)
    reg.histogram("h").observe(2.0)
    snap = reg.snapshot()
    assert snap == {
        "c{host=a}": 3,
        "g": 7,
        "h_count": 1,
        "h_sum": 2.0,
        "h_min": 2.0,
        "h_max": 2.0,
    }
    # key ORDER is deterministic (sorted names; histograms expand in a
    # fixed suffix order), so snapshots diff cleanly run-to-run
    assert list(snap) == list(reg.snapshot())

    reg.counter("c").inc(2, host="a")
    reg.gauge("g").set(4)  # gauges take the NEWER value in a delta
    reg.histogram("h").observe(6.0)
    d = reg.delta(snap)
    assert d["c{host=a}"] == 2
    assert d["g"] == 4
    assert d["h_count"] == 1 and d["h_sum"] == 6.0


# ---------------------------------------------------------------------------
# FlightRecorder
# ---------------------------------------------------------------------------


def test_ring_bound_drop_accounting_and_kind_filter():
    fr = FlightRecorder(capacity=3, clock=Tick())
    for i in range(5):
        fr.record("tick", i=i)
    fr.record("other")
    assert len(fr) == 3
    assert fr.dropped == 3
    assert [e["i"] for e in fr.events("tick")] == [3, 4]
    # seq is monotonic and survives eviction (total order over the run)
    assert [e["seq"] for e in fr.events()] == [3, 4, 5]
    with pytest.raises(ValueError, match="capacity"):
        FlightRecorder(capacity=0)


def test_dump_schema_and_auto_dump_never_raises(tmp_path):
    path = str(tmp_path / "flight.json")
    fr = FlightRecorder(capacity=8, dump_path=path, clock=Tick())
    fr.record("tuner_decision", chosen="q")
    assert fr.auto_dump("barrier_abort epoch 1") == path
    with open(path) as f:
        payload = json.load(f)
    assert payload["schema"] == "repro.flight_recorder/1"
    assert payload["reason"] == "barrier_abort epoch 1"
    assert payload["recorded_total"] == 1 and payload["dropped"] == 0
    assert payload["events"][0]["kind"] == "tuner_decision"
    assert fr.dumps_written == 1

    # a broken disk must not mask the original failure
    fr.dump_path = str(tmp_path / "no" / "such" / "dir" / "f.json")
    assert fr.auto_dump("worker failure") is None
    assert FlightRecorder(clock=Tick()).auto_dump("no path configured") is None


# ---------------------------------------------------------------------------
# DriftMonitor + TelemetryBus self-reporting + the de-flaked bench fraction
# ---------------------------------------------------------------------------


def _timing(plan="p", seconds=2.0, source="sim", index=0):
    return SimpleNamespace(
        plan=SimpleNamespace(name=plan), seconds=seconds, source=source,
        index=index, end_time=float(index),
    )


def test_drift_monitor_median_join_and_skips():
    reg = MetricsRegistry()
    preds = {"p": 2.0}
    mon = DriftMonitor(lambda name: preds.get(name), registry=reg, window=4,
                       source="sim")
    assert mon.ratio() == 1.0  # before any sample
    mon.on_iteration(_timing(seconds=2.0))   # ratio 1.0
    mon.on_iteration(_timing(seconds=3.0))   # ratio 1.5
    mon.on_iteration(_timing(seconds=4.0))   # ratio 2.0 -> median 1.5
    assert mon.ratio() == pytest.approx(1.5)
    assert reg.gauge("model_drift_ratio").value() == pytest.approx(1.5)
    assert mon.samples == 3

    mon.on_iteration(_timing(plan="unknown"))          # no prediction
    mon.on_iteration(_timing(source="engine"))         # filtered source
    mon.on_iteration(_timing(seconds=0.0))             # degenerate sample
    assert mon.samples == 3
    assert reg.counter("drift_samples_joined_total").value() == 3
    assert reg.counter("drift_samples_skipped_total").value() == 2  # filter ≠ skip


def test_drift_alert_rising_edge_records_one_flight_event():
    fr = FlightRecorder(clock=Tick())
    mon = DriftMonitor(lambda name: 1.0, window=2, alert_threshold=0.5,
                       flight=fr)
    mon.on_iteration(_timing(seconds=1.1))
    assert not mon.drifting and not fr.events("drift_alert")
    mon.on_iteration(_timing(seconds=3.0))  # median(1.1, 3.0) = 2.05 > 1.5
    assert mon.drifting
    mon.on_iteration(_timing(seconds=3.0))  # still drifting: no second event
    (alert,) = fr.events("drift_alert")
    assert alert["ratio"] == pytest.approx(2.05)


def test_telemetry_bus_self_reports_per_source():
    from repro.runtime.telemetry import TelemetryBus

    reg = MetricsRegistry()
    bus = TelemetryBus(metrics=reg)
    seen = []
    bus.subscribe(seen.append)
    bus.publish(_timing(seconds=2.0, source="sim"))
    bus.publish(_timing(seconds=4.0, source="sim"))
    bus.publish(_timing(seconds=1.0, source="engine"))
    assert len(seen) == 3
    assert reg.counter("telemetry_published_total").value(source="sim") == 2
    assert reg.counter("telemetry_published_total").value(source="engine") == 1
    assert reg.histogram("telemetry_iteration_seconds").value(source="sim").sum == 6.0


def test_warm_switch_frac_from_trace_median_definition():
    from repro.launch.train_adaptive import warm_switch_frac_from_trace

    rec = TraceRecorder(clock=Tick())
    for i, dur in enumerate((1.0, 2.0, 9.0)):  # median 2.0 absorbs the outlier
        rec.add_span("host0/iterations", f"iter {i}", float(i * 10), dur)
    rec.add_span("host0/switches", "switch a", 0.5, 0.1, warm=True)
    rec.add_span("host0/switches", "switch b", 10.5, 0.3, warm=True)
    rec.add_span("host0/switches", "cold", 20.5, 5.0, warm=False)  # excluded
    frac = warm_switch_frac_from_trace(rec.to_chrome_trace())
    assert frac == pytest.approx(0.2 / 2.0)

    empty = TraceRecorder(clock=Tick())
    assert warm_switch_frac_from_trace(empty.to_chrome_trace()) is None


# ---------------------------------------------------------------------------
# integration: fabric dict shapes, TuningRecord back-compat, fleet trace
# ---------------------------------------------------------------------------

FABRIC_METRICS_SHAPE = {
    "hosts", "telemetry_windows", "telemetry_rounds_dropped",
    "telemetry_retention", "barrier_epochs", "committed_switches",
    "aborted_switches", "barrier_latency_max", "incumbent",
}


def test_fabric_metrics_dict_shape_frozen_over_registry():
    """The regression contract for satellite consumers
    (``benchmarks/trajectory.py``, the distributed CI artifact): migrating
    the values onto the registry must not move a single key."""
    from repro.core.kinds import ScheduleSpec
    from repro.runtime.fabric import CoordinatorServer

    server = CoordinatorServer(
        ("a", "b"), initial_spec=ScheduleSpec(kind="kfkb", k=1, micro_batch_size=2)
    )
    fab = server.fabric_metrics()
    assert set(fab) == FABRIC_METRICS_SHAPE
    assert fab["hosts"] == 2 and fab["barrier_epochs"] == 0
    assert isinstance(fab["incumbent"], dict)
    # the registry snapshot rides along additively on the trace export
    trace = server.telemetry_trace()
    assert trace["registry"]["fabric_hosts"] == 2
    assert trace["metrics"] == fab  # legacy alias stays the same dict


def test_tuning_record_rejected_candidates_back_compat():
    from repro.core.tuner import AutoTuner, TuningRecord

    rec = TuningRecord(time=0.0, estimates={"a": 1.0}, chosen="a",
                       chosen_k=1, switched=False)
    assert rec.rejected_candidates == ()  # pre-PR-9 construction still valid

    rejections = AutoTuner._rejections(
        {"win": 10.0, "slow": 12.0, "tie": 10.0}, "win"
    )
    assert [n for n, _, _ in rejections] == ["tie", "slow"]  # best-first
    assert "wins deterministic order" in rejections[0][2]
    assert "20.0% slower" in rejections[1][2]


def test_fleet_trace_carries_acceptance_contract():
    """One scripted two-host fleet, one shared Observability bundle: the
    exported trace must hold both hosts' iteration spans, the tuner's
    per-candidate decision trail, and a full PREPARE -> COMMIT epoch; the
    flight ring must hold the structured trail behind it; and the
    ``CacheStats`` view must agree with the registry it reads from."""
    from repro.launch.train_adaptive import (
        build_fabric_fleet,
        fig10_parts,
        run_fabric_rounds,
    )

    _, _, cands, _ = fig10_parts(2, d_model=8)
    target = cands[1].spec

    def one_shot(server):
        return target if not server.barrier.history else None

    obs = Observability.create(clock=Tick())
    server, workers = build_fabric_fleet(
        num_hosts=2, num_stages=2, d_model=8, seq_len=16,
        vote_timeout=600.0, decision_fn=one_shot, obs=obs,
    )
    try:
        out = run_fabric_rounds(server, workers, 5)
    finally:
        for w in workers:
            w.runtime.cache.shutdown()

    payload = obs.trace.to_chrome_trace()
    validate_chrome_trace(payload)
    tracks = set(spans_by_track(payload))
    assert {"host0/iterations", "host1/iterations", "coordinator/barrier"} <= tracks

    instants = [e["name"] for e in payload["traceEvents"] if e["ph"] == "i"]
    assert any(n.startswith("PREPARE epoch") for n in instants)
    assert any(n.startswith("COMMIT epoch") for n in instants)
    assert any(n.startswith("decision ") for n in instants)  # tuner trail

    # the structured trail behind the verdict
    (decision,) = [fr for fr in obs.flight.events("tuner_decision")[:1]]
    assert set(decision) >= {"chosen", "estimates", "rejected", "switched"}
    (verdict,) = obs.flight.events("barrier_verdict")
    assert verdict["committed"] and len(obs.flight.events("barrier_vote")) == 2
    assert out["fabric"]["committed_switches"] == 1

    # CacheStats back-compat: still a dataclass view, but its values are the
    # shared registry's per-track series (one registry, per-host stats)
    stats = workers[0].runtime.cache.stats
    assert dataclasses.asdict(stats)  # legacy consumers still asdict() it
    assert stats.gets > 0 and 0.0 <= stats.hit_rate <= 1.0
    assert stats.gets == int(
        obs.metrics.counter("cache_gets_total").value(track="host0")
    )
