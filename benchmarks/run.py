"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

Runs one benchmark per paper table/figure plus the roofline report:

  Fig 2  pipeline_length   — 1F1B vs kFkB length under preemption
  Fig 6  granularity       — k sweep at fixed global batch, busy rounds
  Fig 7  weak_scaling (UNet)
  Fig 8  weak_scaling (GPT params ladder)
  Fig 9  strong_scaling    — + SPMD-only comparison
  Fig 10 adaptive_tuning   — hourly online tuning across regimes
  (g)    roofline          — per-(arch × shape × mesh) terms from dry-run

Results land in experiments/results/*.json; each module also asserts the
paper's qualitative claims so this doubles as an integration gate.

``--dry-run`` (the CI smoke) imports every suite module and exercises one
tiny simulation per schedule kind instead of the full sweeps.
"""

from __future__ import annotations

import os
import sys
import time
import traceback

# self-locating: `python benchmarks/run.py` works from any cwd without
# PYTHONPATH gymnastics (repo root for the benchmarks package, src for repro)
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def dry_run() -> int:
    """CI smoke: every suite module imports, every schedule kind simulates."""
    from benchmarks import (  # noqa: F401 - import is the smoke
        adaptive_tuning,
        granularity,
        pipeline_length,
        roofline,
        strong_scaling,
        trajectory,
        weak_scaling,
    )
    from benchmarks.common import ensure_results_dir
    from repro.core import StableTrace, StageCosts, simulate_plan, uniform_network
    from repro.core.kinds import ScheduleSpec
    from repro.core.schedule import make_plan

    ensure_results_dir()  # a fresh clone must survive its first write
    S, M = 4, 8
    costs = StageCosts.uniform(S, 1.0, act_bytes=1.0)
    net = uniform_network(S, lambda: StableTrace(4.0))
    # one cell per REGISTERED kind (so a newly registered kind simulates in
    # this smoke automatically), plus hand-picked composition extras
    from repro.core.kinds import get_kind, registered_kinds

    cells = []
    for kind in registered_kinds():
        spec = get_kind(kind)
        cells.append(
            (kind, 1, spec.virtual_axis((2,))[0], 1 if spec.requires_warmup else 0)
        )
    cells += [
        ("kfkb", 2, 1, 0),
        ("zb_h2", 1, 1, (0, 1, 2, 1)),  # heterogeneous warmup vector
        ("interleaved_zb", 1, 2, (1, 0, 2, 1)),  # interleaved H2
    ]
    for kind, k, v, w in cells:
        plan = make_plan(S, M, spec=ScheduleSpec(kind=kind, k=k, num_virtual=v, extra_warmup=w))
        res = simulate_plan(plan, costs, net)
        print(f"[dry-run] {plan.name:28s} length={res.pipeline_length:7.2f} "
              f"bubble={res.bubble_fraction:.3f}")
    print("[dry-run] all benchmark modules import; schedule family simulates OK")
    return 0


def main() -> int:
    if "--dry-run" in sys.argv[1:]:
        return dry_run()
    from benchmarks import (
        adaptive_tuning,
        granularity,
        pipeline_length,
        roofline,
        strong_scaling,
        trajectory,
        weak_scaling,
    )
    from benchmarks.common import ensure_results_dir

    ensure_results_dir()

    def run_trajectory():
        if trajectory.main(["--check"]) != 0:
            raise RuntimeError("trajectory regression gate failed")

    suites = [
        ("pipeline_length (Fig 2)", pipeline_length.run),
        ("granularity (Fig 6)", granularity.run),
        ("weak_scaling (Figs 7+8)", weak_scaling.run),
        ("strong_scaling (Fig 9)", strong_scaling.run),
        ("adaptive_tuning (Fig 10)", adaptive_tuning.run),
        ("roofline single-pod (g)", lambda: roofline.run("single")),
        ("roofline multi-pod (g)", lambda: roofline.run("multi")),
        ("trajectory (CI gate)", run_trajectory),
    ]
    failures = []
    for name, fn in suites:
        t0 = time.time()
        print(f"\n{'=' * 72}\nBENCH {name}\n{'=' * 72}")
        try:
            fn()
            print(f"[PASS] {name} ({time.time() - t0:.1f}s)")
        except Exception as e:
            failures.append(name)
            print(f"[FAIL] {name}: {e}")
            traceback.print_exc()
    print(f"\n{'=' * 72}")
    print(f"benchmarks: {len(suites) - len(failures)}/{len(suites)} passed")
    if failures:
        print("failed:", ", ".join(failures))
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
