"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

Runs one benchmark per paper table/figure plus the roofline report:

  Fig 2  pipeline_length   — 1F1B vs kFkB length under preemption
  Fig 6  granularity       — k sweep at fixed global batch, busy rounds
  Fig 7  weak_scaling (UNet)
  Fig 8  weak_scaling (GPT params ladder)
  Fig 9  strong_scaling    — + SPMD-only comparison
  Fig 10 adaptive_tuning   — hourly online tuning across regimes
  (g)    roofline          — per-(arch × shape × mesh) terms from dry-run

Results land in experiments/results/*.json; each module also asserts the
paper's qualitative claims so this doubles as an integration gate.
"""

from __future__ import annotations

import sys
import time
import traceback


def main() -> int:
    from benchmarks import (
        adaptive_tuning,
        granularity,
        pipeline_length,
        roofline,
        strong_scaling,
        weak_scaling,
    )

    suites = [
        ("pipeline_length (Fig 2)", pipeline_length.run),
        ("granularity (Fig 6)", granularity.run),
        ("weak_scaling (Figs 7+8)", weak_scaling.run),
        ("strong_scaling (Fig 9)", strong_scaling.run),
        ("adaptive_tuning (Fig 10)", adaptive_tuning.run),
        ("roofline single-pod (g)", lambda: roofline.run("single")),
        ("roofline multi-pod (g)", lambda: roofline.run("multi")),
    ]
    failures = []
    for name, fn in suites:
        t0 = time.time()
        print(f"\n{'=' * 72}\nBENCH {name}\n{'=' * 72}")
        try:
            fn()
            print(f"[PASS] {name} ({time.time() - t0:.1f}s)")
        except Exception as e:
            failures.append(name)
            print(f"[FAIL] {name}: {e}")
            traceback.print_exc()
    print(f"\n{'=' * 72}")
    print(f"benchmarks: {len(suites) - len(failures)}/{len(suites)} passed")
    if failures:
        print("failed:", ", ".join(failures))
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
