"""Paper Fig. 10 — the adaptive tuning experiment.

Platform-S1-style preempted network over four simulated "hours" with
regime changes (preemption heavy → heavy → eased → heavy again).  Six
candidate plans (k = 1..6 at global batch 192, 8 stages) are kept alive;
the Ada-Grouper tuner re-profiles hourly and switches plans.

Reproduced claims:
* 1F1B (k=1) is estimated worst in preempted hours;
* the tuner's choice tracks the regime (larger k under preemption, smaller
  when it eases — hour 3 in the paper, where all plans converge);
* the chosen plan beats 1F1B by ~20% in preempted hours;
* actual iteration throughput under the coordinator matches the estimates'
  ordering.
"""

from __future__ import annotations

from benchmarks.common import efficiency, markdown_table, save_result
from repro.configs.gpt import GPT_CONFIGS, gpt_stage_costs
from repro.core import (
    AutoTuner,
    BurstyTrace,
    Candidate,
    Coordinator,
    Network,
    NetworkProfiler,
    RegimeTrace,
    make_plan,
)

S = 8
GLOBAL_BATCH = 192
SEQ = 1024
HOUR = 3600.0


def _candidates():
    cands = []
    for k in range(1, 7):
        b = max(6 // k, 1)
        M = GLOBAL_BATCH // b
        plan = make_plan(S, M, k, micro_batch_size=b)
        cands.append(Candidate(k, b, M, plan, est_peak_bytes=0.0))
    return cands


def _costs_for(cand: Candidate):
    costs = gpt_stage_costs(GPT_CONFIGS["GPT-Medium"], S, cand.micro_batch_size, SEQ)
    eff = efficiency(cand.micro_batch_size) / efficiency(6)
    costs.fwd_time = [t / eff for t in costs.fwd_time]
    costs.bwd_time = [t / eff for t in costs.bwd_time]
    return costs


def _network():
    def hourly(seed, heavy):
        if heavy:
            return BurstyTrace(12.5e9, contended_frac=0.12, mean_free=0.3,
                               mean_contended=0.9, seed=seed)
        return BurstyTrace(12.5e9, contended_frac=0.6, mean_free=2.0,
                           mean_contended=0.2, seed=seed)

    def link_trace(a, b):
        seed = a * 17 + b
        return RegimeTrace(
            breakpoints=[1 * HOUR, 2 * HOUR, 3 * HOUR],
            traces=[hourly(seed, True), hourly(seed + 7, True),
                    hourly(seed + 13, False), hourly(seed + 23, True)],
        )

    return Network.build(S, link_trace)


def run() -> dict:
    net = _network()
    cands = _candidates()
    tuner = AutoTuner(cands, _costs_for, NetworkProfiler(net, window=4))
    hours = []
    for h in range(4):
        rec = tuner.tune(now=h * HOUR + 60.0)
        est_sps = {name: GLOBAL_BATCH / est for name, est in rec.estimates.items()}
        hours.append((h, rec, est_sps))
    rows = []
    for h, rec, est in hours:
        base = est[cands[0].name]  # 1F1B estimate this hour
        rows.append(
            [f"hour {h}", rec.chosen_k]
            + [f"{est[c.name] / base:.3f}" for c in cands]
        )
    table = markdown_table(
        ["", "chosen k", *(f"k={c.k}" for c in cands)], rows
    )
    print(f"\n== Fig 10: adaptive tuning, hourly re-evaluation ==")
    print(table)

    # claims
    for h, rec, est in hours:
        best = max(est.values())
        assert est[rec.chosen] == best, "tuner must pick its own argmax throughput"
    heavy_hours = [hours[0], hours[1], hours[3]]
    for h, rec, est in heavy_hours:
        assert rec.chosen_k > 1
        gain = est[rec.chosen] / est[cands[0].name] - 1
        assert gain > 0.05, f"hour {h}: expected >5% over 1F1B, got {gain:.1%}"
    eased_k = hours[2][1].chosen_k
    heavy_ks = [rec.chosen_k for _, rec, _ in heavy_hours]
    assert eased_k <= min(heavy_ks), "eased hour should need no more grouping"

    # run the coordinator through the first hour to confirm realized gains
    coord = Coordinator(
        AutoTuner(cands, _costs_for, NetworkProfiler(net, window=4)),
        net, GLOBAL_BATCH, tuning_interval=HOUR,
    )
    summary = coord.run(6)
    realized = summary.throughput
    fixed_1f1b = Coordinator(
        AutoTuner(cands[:1], _costs_for, NetworkProfiler(net, window=4)),
        net, GLOBAL_BATCH, tuning_interval=HOUR,
    ).run(6).throughput
    print(f"realized throughput: Ada-Grouper {realized:.1f} sps vs fixed 1F1B "
          f"{fixed_1f1b:.1f} sps ({realized / fixed_1f1b - 1:+.1%})")
    assert realized >= fixed_1f1b
    payload = {
        "hours": [
            {"hour": h, "chosen_k": rec.chosen_k,
             "relative": {c.name: est[c.name] / est[cands[0].name] for c in cands}}
            for h, rec, est in hours
        ],
        "realized_gain": realized / fixed_1f1b - 1,
        "table": table,
    }
    save_result("adaptive_tuning", payload)
    return payload


if __name__ == "__main__":
    run()
