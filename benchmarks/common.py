"""Shared benchmark plumbing: result records, markdown tables, output dirs."""

from __future__ import annotations

import json
import os
from typing import Any

RESULTS_DIR = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "experiments", "results")
)


def ensure_results_dir() -> str:
    """Create experiments/results/ (gitignored) so a fresh clone's first
    benchmark write can never fail; every suite's write path funnels here."""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    return RESULTS_DIR


def save_result(name: str, payload: Any) -> str:
    ensure_results_dir()
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=str)
    return path


def markdown_table(headers: list[str], rows: list[list]) -> str:
    out = ["| " + " | ".join(headers) + " |",
           "|" + "|".join("---" for _ in headers) + "|"]
    for r in rows:
        out.append("| " + " | ".join(str(x) for x in r) + " |")
    return "\n".join(out)


def efficiency(b: int, overhead: float = 0.4) -> float:
    """Relative compute efficiency of micro-batch size ``b``: smaller
    micro-batches under-utilize the device (paper §4.1/§6.2.1).  Modeled as
    amortizing a fixed per-launch overhead: eff = b / (b + overhead).
    ``overhead=0.4`` calibrates to the paper's Fig-6 behaviour, where
    mbs=1 plans still sit above 1F1B but stop improving past k≈3."""
    return b / (b + overhead)
