"""Paper Fig. 2 — pipeline length of 1F1B vs kFkB in a preempted network.

Paper assumptions (§4.1): backward = 2 x forward; cross-stage transfer time
= forward / 2.  We reproduce the qualitative claim — kFkB (k > 1) yields a
strictly shorter pipeline than 1F1B when transfers are non-negligible, and
the zero-comm case is schedule-invariant — and quantify the bubble
fractions.
"""

from __future__ import annotations

from benchmarks.common import markdown_table, save_result
from repro.core import StableTrace, StageCosts, make_plan, simulate_plan, uniform_network


def run(S: int = 4, M: int = 8) -> dict:
    t_f = 1.0
    costs = StageCosts.uniform(S, t_f, act_bytes=1.0)  # bwd = 2 fwd
    nets = {
        "exclusive (c≈0)": uniform_network(S, lambda: StableTrace(1e15)),
        "preempted (c=F/2)": uniform_network(S, lambda: StableTrace(2.0)),
        "heavy (c=2F)": uniform_network(S, lambda: StableTrace(0.5)),
    }
    rows = []
    records = {}
    for net_name, net in nets.items():
        lengths = {}
        for k in (1, 2, 4, M):
            res = simulate_plan(make_plan(S, M, k), costs, net)
            lengths[k] = res.pipeline_length
        base = lengths[1]
        rows.append(
            [net_name]
            + [f"{lengths[k]:.2f} ({(base / lengths[k] - 1) * 100:+.1f}%)" for k in (1, 2, 4, M)]
        )
        records[net_name] = lengths
    table = markdown_table(
        ["network", "1F1B", "2F2B", "4F4B", f"GPipe (k={M})"], rows
    )
    print(f"\n== Fig 2: pipeline length, S={S}, M={M}, bwd=2·fwd ==")
    print(table)
    # paper claims
    assert records["preempted (c=F/2)"][2] < records["preempted (c=F/2)"][1], (
        "2F2B must beat 1F1B in the preempted network"
    )
    exclusive = records["exclusive (c≈0)"]
    assert abs(exclusive[1] - exclusive[2]) < 1e-9, "zero-comm: schedule-invariant"
    save_result("pipeline_length", {"records": records, "table": table})
    return records


if __name__ == "__main__":
    run()
