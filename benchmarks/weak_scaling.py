"""Paper Figs. 7 & 8 — weak scaling.

* GPT (Fig 8): parameter-count scaling — workers 1/2/4/8 run GPT-Medium/
  Large/XL/2.7B respectively at global batch 64, per the paper's Table 1.
  We report achieved model FLOP/s (the paper's Megatron-style metric) for
  1F1B vs the best kFkB, on a "cloud" bursty network.
* U-Net (Fig 7): batch-size weak scaling on the UNet-Base / UNet-Medium
  cost proxies, whose cross-stage tensors are 3-5x larger relative to
  compute (paper §6.2.2/§6.2.3) — the regime where kFkB matters most.

Claims reproduced: kFkB >= 1F1B everywhere; largest relative gains on the
communication-heavy U-Net; GPT gains grow with worker count (more stages =
more exposed transfers).
"""

from __future__ import annotations

from benchmarks.common import efficiency, markdown_table, save_result
from repro.configs.gpt import GPT_CONFIGS, UNET_COSTS, gpt_stage_costs
from repro.core import BurstyTrace, make_plan, simulate_plan, uniform_network
from repro.models.common import param_count

GLOBAL_BATCH = 64
SEQ = 1024


def _cloud_net(S, seed=0):
    return uniform_network(
        S, lambda: BurstyTrace(25e9, contended_frac=0.15, mean_free=0.6,
                               mean_contended=0.4, seed=seed)
    )


def _best_k(plan_maker, costs_for, net, ks=(1, 2, 3, 4, 6)):
    out = {}
    for k in ks:
        plan, costs = plan_maker(k)
        if plan is None:
            continue
        out[k] = simulate_plan(plan, costs, net).pipeline_length
    return out


def run_gpt() -> dict:
    ladder = [(1, "GPT-Medium"), (2, "GPT-Large"), (4, "GPT-XL"), (8, "GPT-2.7B")]
    rows, records = [], {}
    for S, name in ladder:
        cfg = GPT_CONFIGS[name]
        if S == 1:  # no pipeline: single stage, no transfers
            b = 4
            costs = gpt_stage_costs(cfg, 1, b, SEQ)
            length = (GLOBAL_BATCH // b) * (costs.fwd_time[0] + costs.bwd_time[0])
            records[name] = {"1F1B": length, "best_k": 1, "kFkB": length}
            rows.append([name, 1, "-", "-", "1.000"])
            continue
        net = _cloud_net(S, seed=S)

        def plan_maker(k, S=S, cfg=cfg):
            b = max(4 // k, 1)
            M = GLOBAL_BATCH // b
            costs = gpt_stage_costs(cfg, S, b, SEQ)
            eff = efficiency(b) / efficiency(4)
            costs.fwd_time = [t / eff for t in costs.fwd_time]
            costs.bwd_time = [t / eff for t in costs.bwd_time]
            return make_plan(S, M, k, micro_batch_size=b), costs

        lengths = _best_k(plan_maker, None, net)
        best_k = min(lengths, key=lengths.get)
        flops = 6 * param_count(cfg) * GLOBAL_BATCH * SEQ
        records[name] = {
            "1F1B": lengths[1],
            "kFkB": lengths[best_k],
            "best_k": best_k,
            "mflops_1f1b": flops / lengths[1] / 1e12,
            "mflops_kfkb": flops / lengths[best_k] / 1e12,
        }
        rows.append([
            name, S, f"{flops / lengths[1] / 1e12:.1f}",
            f"{flops / lengths[best_k] / 1e12:.1f} (k={best_k})",
            f"{lengths[1] / lengths[best_k]:.3f}",
        ])
    table = markdown_table(
        ["config", "workers", "TFLOP/s 1F1B", "TFLOP/s Ada-Grouper", "speedup"], rows
    )
    print(f"\n== Fig 8: GPT weak scaling (params), GB={GLOBAL_BATCH} ==")
    print(table)
    for name, r in records.items():
        assert r["kFkB"] <= r["1F1B"] + 1e-9, name
    save_result("weak_scaling_gpt", {"records": records, "table": table})
    return records


def run_unet() -> dict:
    rows, records = [], {}
    for name, costs_fn in UNET_COSTS.items():
        for S in (2, 4, 8):
            # M8s shares hosts with other jobs (paper §6.1)
            net = uniform_network(
                S, lambda: BurstyTrace(12.5e9, contended_frac=0.3,
                                       mean_free=1.0, mean_contended=0.3,
                                       seed=100 + S),
            )
            B = 128 * S  # paper: global batch = N_workers * 128

            def plan_maker(k, S=S):
                b = max(8 // k, 2)  # UNet-Medium OOMs below b=2 (paper: k=4 OOM)
                M = B // b
                # costs_fn is calibrated at b=8: rescale compute AND bytes to b
                costs = costs_fn(S).scaled_to_microbatch(8, b, efficiency=efficiency)
                return make_plan(S, M, k, micro_batch_size=b), costs

            # UNet-Medium OOMs at k=4 in the paper -> its candidate set stops at 3
            ks = (1, 2, 3) if name == "UNet-Medium" else (1, 2, 3, 4)
            lengths = _best_k(plan_maker, None, net, ks=ks)
            best_k = min(lengths, key=lengths.get)
            gain = lengths[1] / lengths[best_k] - 1
            records[f"{name}@{S}"] = {
                "1F1B": lengths[1], "kFkB": lengths[best_k],
                "best_k": best_k, "gain": gain,
            }
            rows.append([name, S, f"k={best_k}", f"{gain * 100:+.1f}%"])
    table = markdown_table(["config", "workers", "best plan", "gain vs 1F1B"], rows)
    print(f"\n== Fig 7: U-Net weak scaling (batch), comm-heavy stages ==")
    print(table)
    assert all(r["gain"] >= -1e-9 for r in records.values())
    assert max(r["gain"] for r in records.values()) > 0.02, "U-Net should gain 2-14%"
    save_result("weak_scaling_unet", {"records": records, "table": table})
    return records


def run() -> dict:
    return {"gpt": run_gpt(), "unet": run_unet()}


if __name__ == "__main__":
    run()
