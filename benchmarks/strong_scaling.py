"""Paper Fig. 9 — GPT-Medium strong scaling + SPMD-only comparison.

Fixed model (GPT-Medium) and global batch (64), workers 2/4/8; micro-batch
size 1 for pipeline (as in the paper), 8 for SPMD.  The SPMD-only baseline
is modeled the way the paper describes its measured deployments: a
data-parallel-like plan whose per-step communication is the gradient
all-reduce — 0.7–1.4 GB per micro-batch step of transfer vs 2–5x more for
pipeline's repeated activations... inverted: the paper found PIPELINE moves
2-5x LESS data and wins on these platforms; we reproduce that ordering.

Claims: kFkB >= 1F1B (up to ~20%); pipeline (either schedule) beats the
SPMD-only plan on the preempted-network platforms.
"""

from __future__ import annotations

from benchmarks.common import efficiency, markdown_table, save_result
from repro.configs.gpt import GPT_CONFIGS, gpt_stage_costs
from repro.core import BurstyTrace, make_plan, simulate_plan, uniform_network
from repro.models.common import param_count

GLOBAL_BATCH = 64
SEQ = 1024
CFG = GPT_CONFIGS["GPT-Medium"]

PLATFORMS = {
    # (contended_frac, mean_free, mean_contended) — C1x is narrow 25Gb vEth,
    # S1/M8s are 100Gb RoCE shared with production traffic
    "C1x (25Gb vEth)": (3.125e9, 0.25, 0.5, 0.5),
    "S1 (100Gb RoCE)": (12.5e9, 0.20, 0.8, 0.3),
    "M8s (100Gb RoCE, shared hosts)": (12.5e9, 0.25, 0.5, 0.5),
}


def _net(S, bw, frac, free, cont, seed):
    return uniform_network(
        S, lambda: BurstyTrace(bw, contended_frac=frac, mean_free=free,
                               mean_contended=cont, seed=seed)
    )


def _spmd_step_time(S, bw_trace_net):
    """SPMD-only (data-parallel-like) plan, modeled as the paper measured
    it: gradients reduce per MICRO-BATCH (mbs=8), each all-reduce moving
    ~2·P·2(S-1)/S bytes == the paper's observed 0.7-1.4 GB per micro-batch;
    reduction of micro-batch i overlaps the compute of i+1."""
    mbs = 8
    n_mb = GLOBAL_BATCH // mbs
    costs = gpt_stage_costs(CFG, 1, mbs, SEQ)
    t_mb = costs.fwd_time[0] + costs.bwd_time[0]
    grad_bytes = 2.0 * param_count(CFG) * 2.0 * (S - 1) / S
    trace = bw_trace_net.trace(0, 1)
    t_comm = trace.finish_time(0.0, grad_bytes)
    exposed = max(0.0, t_comm - t_mb)
    return n_mb * t_mb + (n_mb - 1) * exposed + t_comm


def run() -> dict:
    rows, records = [], {}
    for plat, (bw, frac, free, cont) in PLATFORMS.items():
        for S in (2, 4, 8):
            net = _net(S, bw, frac, free, cont, seed=hash(plat) % 1000 + S)
            lengths = {}
            for k in (1, 2, 3, 4):
                b = 1  # paper: micro-batch size 1 for pipeline
                M = GLOBAL_BATCH
                costs = gpt_stage_costs(CFG, S, b, SEQ)
                eff = efficiency(b) / efficiency(8)
                costs.fwd_time = [t / eff for t in costs.fwd_time]
                costs.bwd_time = [t / eff for t in costs.bwd_time]
                plan = make_plan(S, M, k, micro_batch_size=b)
                lengths[k] = simulate_plan(plan, costs, net).pipeline_length
            spmd = _spmd_step_time(S, net)
            best_k = min(lengths, key=lengths.get)
            rec = {
                "1F1B": GLOBAL_BATCH / lengths[1],
                "kFkB": GLOBAL_BATCH / lengths[best_k],
                "best_k": best_k,
                "SPMD": GLOBAL_BATCH / spmd,
            }
            records[f"{plat}@{S}"] = rec
            rows.append([
                plat, S,
                f"{rec['1F1B']:.1f}", f"{rec['kFkB']:.1f} (k={best_k})",
                f"{rec['SPMD']:.1f}",
                f"{rec['kFkB'] / rec['1F1B'] - 1:+.1%}",
            ])
    table = markdown_table(
        ["platform", "workers", "1F1B sps", "Ada-Grouper sps", "SPMD sps", "kFkB gain"],
        rows,
    )
    print(f"\n== Fig 9: GPT-Medium strong scaling, GB={GLOBAL_BATCH}, mbs=1 ==")
    print(table)
    for key, r in records.items():
        assert r["kFkB"] >= r["1F1B"] - 1e-9, key
        assert r["kFkB"] >= r["SPMD"], f"pipeline should beat SPMD-only: {key}"
    save_result("strong_scaling", {"records": records, "table": table})
    return records


if __name__ == "__main__":
    run()
