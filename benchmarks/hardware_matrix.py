"""Hardware-matrix conformance: the offline tuner slice per committed device spec.

For each ``specs/*.json`` part this derives the pinned workload's
(`specs/workloads/pinned-4stage.json`) per-stage :class:`StageCosts` and
:class:`MemoryModel` through :mod:`repro.core.devicespec` — pure float
arithmetic, no accelerator, no XLA — then runs the REAL adaptive search on
them: candidate enumeration against the part's capacity curve, the
:class:`AutoTuner` over a stable network at the part's link bandwidth, and
deterministic makespan simulation of the winner vs the 1F1B baseline.  The
resulting slice (derived seconds, candidate set with admitted ``w[s]`` and
``zb_policy[s]`` vectors, estimates, chosen ``ScheduleSpec`` coordinates,
makespan ratios) is compared field-for-field against a golden fixture in
``specs/golden/<spec>.json``.

This is the CI ``hardware-matrix`` job: any cost-model / enumeration /
tuner change that silently alters what the system would do on an H100, an
A100, a TPU v5e, or the two synthetic stress regimes (extreme
compute/memory skew, slow interconnect) fails the matrix — on hardware
nobody in CI owns.  Floats are rounded to 6 significant digits on both
sides, so the comparison is exact-by-construction for deterministic
arithmetic while immune to sub-ppm libm differences.

Usage:
  python benchmarks/hardware_matrix.py                     # check all specs
  python benchmarks/hardware_matrix.py --spec specs/h100-sxm.json --check
  python benchmarks/hardware_matrix.py --update            # regenerate goldens
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from repro.core import (  # noqa: E402
    AutoTuner,
    NetworkProfiler,
    SearchSpace,
    StableTrace,
    enumerate_candidates,
    simulate_plan,
    uniform_network,
)
from repro.core.devicespec import (  # noqa: E402
    TASK_PROGRAMS,
    derive_memory_model,
    derive_stage_costs,
    load_device_spec,
    load_workload_profile,
)

SLICE_SCHEMA_VERSION = 1
GLOBAL_BATCH = 32
PINNED_WORKLOAD = os.path.join(_ROOT, "specs", "workloads", "pinned-4stage.json")
GOLDEN_DIR = os.path.join(_ROOT, "specs", "golden")

#: the matrix's pinned search space — every kind family plus both W
#: policies, capped at k=2 like the trajectory's seeded scenario
SPACE = SearchSpace(
    kinds=("kfkb", "zb_h1", "zb_h2", "zbv", "interleaved"),
    virtual_degrees=(2,),
    max_k=2,
    zb_policies=("double_remat", "saved_residual"),
)


def _round(value, sig: int = 6):
    """Round every float in a JSON-shaped value to ``sig`` significant digits."""
    if isinstance(value, bool):
        return value
    if isinstance(value, float):
        return float(f"{value:.{sig}g}")
    if isinstance(value, dict):
        return {k: _round(v, sig) for k, v in value.items()}
    if isinstance(value, (list, tuple)):
        return [_round(v, sig) for v in value]
    return value


def conformance_slice(spec_path: str, workload_path: str = PINNED_WORKLOAD) -> dict:
    """Derive + enumerate + tune + simulate one part; fully deterministic."""
    spec = load_device_spec(spec_path)
    workload = load_workload_profile(workload_path)
    S = workload.num_stages
    base_costs = derive_stage_costs(workload, spec)
    mm = derive_memory_model(workload)
    limits = spec.limit_curve(S)
    cands = enumerate_candidates(S, GLOBAL_BATCH, mm, limits, space=SPACE)

    costs_by_b = {workload.micro_batch_size: base_costs}

    def costs_for(cand):
        b = cand.micro_batch_size
        if b not in costs_by_b:
            costs_by_b[b] = base_costs.scaled_to_microbatch(
                workload.micro_batch_size, b
            )
        return costs_by_b[b]

    def net():
        return uniform_network(
            S, lambda: StableTrace(spec.link_bandwidth_bytes_per_s)
        )

    tuner = AutoTuner(cands, costs_for, NetworkProfiler(net()))
    rec = tuner.tune(0.0)
    chosen = next(c for c in cands if c.name == rec.chosen)
    one_f1b = min(
        (c for c in cands if c.kind == "kfkb" and c.k == 1),
        key=lambda c: c.num_microbatches,
    )
    makespan_chosen = simulate_plan(
        chosen.plan, costs_for(chosen), net()
    ).pipeline_length
    makespan_1f1b = simulate_plan(
        one_f1b.plan, costs_for(one_f1b), net()
    ).pipeline_length

    return _round(
        {
            "schema_version": SLICE_SCHEMA_VERSION,
            "spec": spec.name,
            "workload": workload.name,
            "dtype": workload.dtype,
            "global_batch": GLOBAL_BATCH,
            "stage_seconds": {
                p: list(getattr(base_costs, f"{p}_time")) for p in TASK_PROGRAMS
            },
            "limit_curve_bytes": list(limits),
            "candidates": [
                {
                    "name": c.name,
                    "kind": c.kind,
                    "k": c.k,
                    "b": c.micro_batch_size,
                    "M": c.num_microbatches,
                    "num_virtual": c.plan.num_virtual,
                    "extra_warmup": list(c.plan.extra_warmup),
                    "zb_policy": list(c.plan.zb_policy),
                    "est_peak_bytes": c.est_peak_bytes,
                }
                for c in cands
            ],
            "estimates": dict(rec.estimates),
            "chosen": {
                "name": rec.chosen,
                "kind": rec.chosen_kind,
                "k": rec.chosen_k,
                "b": chosen.micro_batch_size,
                "num_virtual": rec.chosen_num_virtual,
                "extra_warmup": list(rec.chosen_extra_warmup),
                "zb_policy": list(rec.chosen_zb_policy),
            },
            "makespan_s": {"chosen": makespan_chosen, "one_f1b": makespan_1f1b},
            "makespan_ratio_vs_1f1b": makespan_1f1b / makespan_chosen,
        }
    )


def golden_path(spec_path: str) -> str:
    stem = os.path.splitext(os.path.basename(spec_path))[0]
    return os.path.join(GOLDEN_DIR, f"{stem}.json")


def _diff(prefix: str, got, want, out: list[str]) -> None:
    if isinstance(want, dict) and isinstance(got, dict):
        for key in sorted(set(want) | set(got)):
            if key not in got:
                out.append(f"{prefix}.{key}: missing (golden has {want[key]!r})")
            elif key not in want:
                out.append(f"{prefix}.{key}: unexpected {got[key]!r}")
            else:
                _diff(f"{prefix}.{key}", got[key], want[key], out)
    elif isinstance(want, list) and isinstance(got, list):
        if len(got) != len(want):
            out.append(f"{prefix}: length {len(got)} != golden {len(want)}")
        for i, (g, w) in enumerate(zip(got, want)):
            _diff(f"{prefix}[{i}]", g, w, out)
    elif got != want:
        out.append(f"{prefix}: {got!r} != golden {want!r}")


def check_spec(spec_path: str) -> list[str]:
    """Diff the live slice against the committed golden (empty = conformant)."""
    record = conformance_slice(spec_path)
    gp = golden_path(spec_path)
    if not os.path.exists(gp):
        return [f"{gp}: golden fixture missing — run with --update and commit it"]
    with open(gp) as f:
        golden = json.load(f)
    out: list[str] = []
    _diff(os.path.basename(spec_path), record, golden, out)
    return out


def all_spec_paths() -> list[str]:
    return sorted(glob.glob(os.path.join(_ROOT, "specs", "*.json")))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--spec", action="append", default=None,
                    help="spec file(s) to run (default: all of specs/*.json)")
    ap.add_argument("--check", action="store_true",
                    help="fail on any drift vs specs/golden/<spec>.json")
    ap.add_argument("--update", action="store_true",
                    help="(re)write the golden fixtures from the live slices")
    args = ap.parse_args(argv)

    paths = args.spec or all_spec_paths()
    failures: list[str] = []
    for path in paths:
        if args.update:
            os.makedirs(GOLDEN_DIR, exist_ok=True)
            record = conformance_slice(path)
            with open(golden_path(path), "w") as f:
                json.dump(record, f, indent=1)
                f.write("\n")
            print(f"[hardware-matrix] wrote {golden_path(path)}")
            continue
        record = conformance_slice(path)
        chosen = record["chosen"]
        print(
            f"[hardware-matrix] {record['spec']}: chose {chosen['name']} "
            f"(kind={chosen['kind']} k={chosen['k']} b={chosen['b']} "
            f"zb={','.join(sorted(set(chosen['zb_policy'])))}) "
            f"ratio_vs_1f1b={record['makespan_ratio_vs_1f1b']}"
        )
        if args.check:
            diffs = check_spec(path)
            if diffs:
                failures.extend(diffs)
                print(f"[hardware-matrix] DRIFT on {os.path.basename(path)}:")
                for d in diffs[:20]:
                    print("  -", d)
                if len(diffs) > 20:
                    print(f"  ... and {len(diffs) - 20} more")
    if failures:
        print(f"[hardware-matrix] {len(failures)} drift(s) — if intentional, "
              f"regenerate with --update and commit the goldens")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
