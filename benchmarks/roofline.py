"""Roofline report — reads the dry-run artifacts (launch/dryrun.py) and
renders the per-(arch × shape × mesh) table of the three roofline terms,
dominant bottleneck, MODEL_FLOPS/HLO_FLOPS ratio and per-device memory.

This is deliverable (g): no pass/fail gate; the table + §Perf iteration
log in EXPERIMENTS.md are the artifact.
"""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import markdown_table, save_result

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load_records(mesh: str = "single") -> list[dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}.json"))):
        r = json.load(open(f))
        out.append(r)
    return out


def _fmt_ms(x):
    return f"{x * 1e3:,.1f}"


def run(mesh: str = "single") -> dict:
    records = load_records(mesh)
    if not records:
        print(f"no dry-run artifacts for mesh={mesh}; run launch/dryrun.py first")
        return {}
    rows = []
    ok = skip = fail = 0
    for r in records:
        tag = r.get("tag", "?").replace(f"__{mesh}", "")
        if r.get("skipped"):
            rows.append([tag, "—", "documented skip", "", "", "", "", ""])
            skip += 1
            continue
        if "error" in r:
            rows.append([tag, "—", "ERROR", r["error"][:40], "", "", "", ""])
            fail += 1
            continue
        ok += 1
        # recompute the useful-flops ratio with the step-kind-correct
        # MODEL_FLOPS (fwd-only prefill is 2ND, not 6ND)
        tokens = r["seq_len"] * r["global_batch"]
        if r["step_kind"] == "train_step":
            mf = 6.0 * r["params_active"] * tokens
        elif r["step_kind"] == "prefill":
            mf = 2.0 * r["params_active"] * tokens
        else:
            mf = 2.0 * r["params_active"] * r["global_batch"]
        if r.get("flops_per_device"):
            r["useful_flops_fraction"] = (mf / r["chips"]) / r["flops_per_device"]
        t = r["roofline"]
        mem_gb = (r["memory"]["argument_bytes"] or 0) / 1e9
        rows.append([
            tag,
            r["step_kind"],
            _fmt_ms(t["compute_s"]),
            _fmt_ms(t["memory_s"]),
            _fmt_ms(t["collective_s"]),
            t["bottleneck"],
            f"{(r.get('useful_flops_fraction') or 0):.2f}",
            f"{mem_gb:.1f}",
        ])
    table = markdown_table(
        ["arch × shape", "step", "compute ms", "memory ms", "collective ms",
         "bound", "6ND/HLO", "args GB/dev"],
        rows,
    )
    print(f"\n== Roofline terms per (arch × shape), mesh={mesh} "
          f"({ok} ok / {skip} skips / {fail} fail) ==")
    print(table)
    save_result(f"roofline_{mesh}", {"rows": rows, "table": table,
                                     "ok": ok, "skip": skip, "fail": fail})
    assert fail == 0, f"{fail} dry-run pairs failed"
    return {"ok": ok, "skip": skip, "fail": fail, "table": table}


if __name__ == "__main__":
    import sys

    run(sys.argv[1] if len(sys.argv) > 1 else "single")
