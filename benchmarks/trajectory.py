"""Benchmark-trajectory gate: pinned, seeded metrics committed per PR.

The repo had no performance history: nothing in CI would notice a PR that
halved simulator throughput or regressed schedule quality.  This suite runs
a PINNED, fully seeded subset of the paper benchmarks —

* **Fig-2 pipeline-length ratios** — 1F1B vs kFkB gains in the preempted
  network (deterministic discrete-event simulation),
* **tuner-switch counts** on a seeded Fig-10-style regime trace (the
  adaptive loop's decision trajectory, deterministic given the trace
  seeds),
* **vector-w gain** — the heterogeneous-warmup golden scenario's
  best-scalar / vector length ratio (this PR's tentpole, now a tracked
  number),
* **simulator events/sec** — wall-clock throughput of the discrete-event
  core on a fixed workload (reported for trend-watching but NOT gated
  since PR 8 — the cost-dependent simulation behaviour it used to proxy is
  now gated deterministically by the device-spec metrics below),
* **device-spec matrix** (PR 8) — the offline hardware-matrix slice
  (``benchmarks/hardware_matrix.py``) on three parts: the pinned
  workload's spec-derived seconds drive candidate enumeration + tuner +
  makespan simulation for ``h100-sxm`` and the two synthetic stress specs,
  gating that the extreme-skew regime deterministically flips the chosen
  ``ScheduleSpec`` away from the H100's pick, plus the H100 makespan and
  the slow-interconnect/H100 makespan ratio — all spec-derived seconds,
  zero wall-clock,
* **live plan-switch runtime** — the seeded Fig-10 regime run through
  ``PlanRuntime`` (real compiled steps, reference backend): kind-switch
  count, precompile hit rate on the tuner's candidate stream, warm-cache
  switch latency as a fraction of one iteration (wall-clock, median over
  trace spans since PR 9), the probe overhead passive telemetry saves vs
  suspend-and-probe, and (PR 9) the ``model_drift_ratio``
  predicted-vs-observed gauge plus the flight-recorder decision count,
* **coordinator fabric** — a two-host ``LocalTransport`` fleet driven
  through a scripted refusal (fleet-wide abort) and a committed warm
  switch: barrier verdict counts, the committed epoch's ready-vote count
  (deterministic, gated — replaces the old wall-clock commit-latency gate;
  the latency itself is still reported), and the worst per-host
  precompile hit rate,
* **saved-residual zero-bubble** — the no-remat ``BWD_WEIGHT`` body:
  simulated makespan gain of ``zb_policy="saved_residual"`` over
  double-remat on a W-heavy pipeline under preemption, the tuner's
  per-stage policy trail on a stage-0-tight limit curve, and (runtime
  suite) the compiled-HLO FLOP ratio of the two W bodies on real stage
  kernels — all deterministic,
* **adaptive decode serving** (PR 10) — the seeded Fig-10 serving
  scenario (``repro.launch.serve_adaptive``) head-to-head against the
  static 1F1B decode baseline on identical seeds: p99 token-latency
  ratio, the serve tuner's kind diversity, SLO attainment, and the
  bursty-vs-exclusive regime-divergent ``ScheduleSpec`` choice — all on
  the simulated clock, deterministic,

— and writes them as schema-versioned ``BENCH_<tag>.json`` at the repo
root.  The CI ``bench`` job (main only) runs ``--check``: against the most
recent previously committed ``BENCH_*.json`` (when one exists), any gated
metric that regresses beyond its tolerance fails the job.  Each PR that
touches performance commits its own ``BENCH_<tag>.json``, growing the
trajectory.

Usage:
  python benchmarks/trajectory.py                 # print metrics
  python benchmarks/trajectory.py --out BENCH_PR3.json [--check]
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import re
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from repro.core import (  # noqa: E402
    AutoTuner,
    BurstyTrace,
    MemoryModel,
    Network,
    NetworkProfiler,
    RegimeTrace,
    ScheduleSpec,
    SearchSpace,
    StableTrace,
    StageCosts,
    enumerate_candidates,
    make_plan,
    simulate_plan,
    uniform_network,
)
from repro.core.network import PeriodicPreemptionTrace  # noqa: E402

SCHEMA_VERSION = 1
REL_TOL = 0.10  # >10% regression on a gated deterministic metric fails the job

#: metric -> (direction, rel_tol); "higher" means bigger is better and the
#: gate requires ``new >= old * (1 - tol)`` (resp. <= for "lower").  Every
#: gate is deterministic at the tight default band except
#: ``runtime_warm_switch_frac`` — the ONE remaining wall-clock gate (a
#: real compiled-step latency fraction with no spec-derived equivalent:
#: it measures host re-stacking work, not schedule cost), which keeps the
#: wide band + fingerprint guard.  ``sim_events_per_sec`` and
#: ``fabric_barrier_latency_commit`` were demoted in PR 8 from wall-clock
#: gates to reported-only metrics; their cost-dependent content is gated
#: deterministically by the spec_* and fabric_commit_ready_votes gates.
GATES = {
    "fig2_gain_k2": ("higher", REL_TOL),
    "fig2_gain_k4": ("higher", REL_TOL),
    "vector_w_gain": ("higher", REL_TOL),
    "tuner_preempted_hours_beat_1f1b": ("higher", REL_TOL),
    # ZB-V (PR 5): the registry-only member's controllable-memory trade —
    # makespan parity with 1F1B under preemption at ~half the plain
    # interleaved peak-live count (both deterministic simulation)
    "zbv_preempted_gain_vs_1f1b": ("higher", REL_TOL),
    "zbv_peak_live_ratio_vs_interleaved": ("higher", REL_TOL),
    # device-spec matrix (PR 8): offline spec-derived seconds, deterministic
    "spec_divergent_choice": ("higher", 0.0),
    "spec_h100_makespan_s": ("lower", REL_TOL),
    "spec_slow_link_makespan_ratio": ("higher", REL_TOL),
    # live plan-switch runtime (PR 4): the adaptive loop on the real engine
    "runtime_kind_switches": ("higher", 0.0),
    "runtime_precompile_hit_rate": ("higher", REL_TOL),
    "runtime_probe_overhead_saved_frac": ("higher", REL_TOL),
    "runtime_warm_switch_frac": ("lower", 0.5),
    # observability (PR 9): the cost model must keep predicting iteration
    # time — model_drift_ratio joins simulated iteration durations against
    # the tuner's estimates (rolling median, source="sim" only, so it is
    # deterministic: both sides are spec/cost arithmetic, no wall clock) —
    # and the tuner decision trail must keep landing in the flight ring
    "model_drift_ratio": ("lower", REL_TOL),
    "tuner_decision_logged": ("higher", 0.0),
    # tuner trajectory (PR 6): the decision trail must keep crossing kinds
    "tuner_kind_diversity": ("higher", 0.0),
    # coordinator fabric (PR 6): the scripted two-host trail must keep its
    # one refused epoch (fleet-wide abort) and one committed warm switch,
    # and precompilation must keep the boundary switch on the warm path
    "fabric_committed_switches": ("higher", 0.0),
    "fabric_aborted_switches": ("higher", 0.0),
    "fabric_precompile_hit_rate_min": ("higher", REL_TOL),
    "fabric_commit_ready_votes": ("higher", 0.0),
    # saved-residual zero-bubble (PR 7): the no-remat W body must keep
    # beating double-remat on the W-heavy preemption cell, the tuner must
    # keep choosing saved_residual exactly on the admitting stages, and the
    # real compiled W kernels must keep the FLOP gap (the eliminated
    # rematerialized forward) on every stage
    "saved_residual_gain_vs_double_remat": ("higher", REL_TOL),
    "sr_tuner_mixed_selected": ("higher", 0.0),
    "sr_w_flops_ratio_min": ("higher", REL_TOL),
    # adaptive decode serving (PR 10): adaptive must keep beating the
    # static 1F1B decode pipeline on p99 token latency under the seeded
    # Fig-10 preemption regimes, the serve tuner's trail must keep
    # crossing schedule kinds, SLO attainment must not regress, and the
    # preempted-vs-exclusive regimes must keep choosing different specs —
    # all simulated-clock deterministic
    "serve_p99_ratio_vs_static_1f1b": ("higher", REL_TOL),
    "serve_tuner_kind_diversity": ("higher", 0.0),
    "serve_slo_attainment": ("higher", REL_TOL),
    "serve_regime_divergent_choice": ("higher", 0.0),
}

#: wall-clock metrics only gate against a baseline recorded on a comparable
#: machine — a BENCH committed from a dev laptop must not fail the CI
#: runner (or vice versa) on hardware difference alone; on a fingerprint
#: mismatch they are reported but not gated.  Since PR 8 this guard covers
#: exactly one gate (see the GATES note); ``sim_events_per_sec`` and
#: ``fabric_barrier_latency_commit`` remain in the report but not in GATES.
#: PR 9 de-flaked the surviving gate's *definition*: the fraction is now
#: ``median(switch span) / median(iteration span)`` over the runtime's
#: trace spans (``train_adaptive.warm_switch_frac_from_trace``) instead of
#: a single max-switch / mean-iteration quotient — one slow outlier
#: iteration or GC pause no longer swings the ratio.  The *spans* are
#: still real host re-stacking time, so it stays fingerprint-guarded.
WALL_CLOCK_METRICS = {
    "runtime_warm_switch_frac",
}


def machine_fingerprint() -> dict:
    return {
        "platform": platform.platform(),
        "machine": platform.machine(),
        "cpu_count": os.cpu_count(),
    }


def fig2_ratios() -> dict:
    """Fig-2 pinned cell: S=4, M=8, bwd=2·fwd, transfer = F/2."""
    S, M = 4, 8
    costs = StageCosts.uniform(S, 1.0, act_bytes=1.0)
    net = uniform_network(S, lambda: StableTrace(2.0))
    lengths = {
        k: simulate_plan(make_plan(S, M, k), costs, net).pipeline_length
        for k in (1, 2, 4)
    }
    return {
        "fig2_len_1f1b": lengths[1],
        "fig2_gain_k2": lengths[1] / lengths[2],
        "fig2_gain_k4": lengths[1] / lengths[4],
    }


def vector_w_gain() -> dict:
    """The heterogeneity golden scenario: memory-skewed 4-stage pipeline
    under periodic preemption; gain = best-admissible-scalar / vector."""
    S, M = 4, 32
    costs = StageCosts.uniform(S, 1.0, act_bytes=1.0)
    net = uniform_network(
        S, lambda: PeriodicPreemptionTrace(high=50.0, low=0.5, period=20.0, duty=0.3)
    )
    vec = make_plan(S, M, spec=ScheduleSpec(kind="zb_h2", extra_warmup=(3, 3, 2, 1)))
    scal = make_plan(S, M, spec=ScheduleSpec(kind="zb_h2", extra_warmup=1))
    len_v = simulate_plan(vec, costs, net).pipeline_length
    len_s = simulate_plan(scal, costs, net).pipeline_length
    return {
        "vector_w_len": len_v,
        "scalar_w_len": len_s,
        "vector_w_gain": len_s / len_v,
    }


def zbv_ratios() -> dict:
    """ZB-V (the registry-only family member) on the pinned preemption
    cell: simulated makespan vs 1F1B (>= 1.0 means the V is no worse
    despite its capped memory) and worst-device peak live vs the
    equal-(S, M, k) plain interleaved plan (> 1.0 means cheaper)."""
    from repro.core.schedule import peak_live_activations

    S, M = 4, 16
    costs = StageCosts.uniform(S, 1.0, act_bytes=1.0)

    def trace():
        return PeriodicPreemptionTrace(high=50.0, low=0.5, period=20.0, duty=0.3)

    len_1f1b = simulate_plan(
        make_plan(S, M, 1), costs, uniform_network(S, trace)
    ).pipeline_length
    zbv = make_plan(S, M, spec=ScheduleSpec(kind="zbv"))
    len_zbv = simulate_plan(zbv, costs, uniform_network(S, trace)).pipeline_length
    peak_zbv = max(peak_live_activations(zbv))
    peak_il = max(
        peak_live_activations(make_plan(S, M, spec=ScheduleSpec(kind="interleaved", num_virtual=2)))
    )
    return {
        "zbv_preempted_len": len_zbv,
        "zbv_preempted_gain_vs_1f1b": len_1f1b / len_zbv,
        "zbv_peak_live": peak_zbv,
        "zbv_peak_live_ratio_vs_interleaved": peak_il / peak_zbv,
    }


def saved_residual_metrics() -> dict:
    """Saved-residual zero-bubble on the pinned W-heavy preemption cell.

    * **simulator gain** — identical zb_h1 schedule shape, W-heavy costs
      (double-remat W = remat forward + pullback at 2.0, saved-residual W
      = pure pullback at 1.0); gain = DR length / SR length.  The drain of
      ``M`` W bodies per stage sets the tail, so eliminating the remat
      shortens the makespan deterministically.
    * **tuner policy trail** — the acceptance shape: a limit curve tight
      on stage 0 and generous elsewhere; the enumeration emits the DR
      baseline plus the mixed vector and the tuner must select
      saved_residual exactly on the admitting stages (``sr_tuner_mixed_
      selected`` gates the deterministic pick).
    """
    S, M = 4, 16
    costs = StageCosts(
        fwd_time=[1.0] * S, bwd_time=[3.0] * S,
        fwd_bytes=[1.0] * S, bwd_bytes=[1.0] * S,
        bwd_input_time=[1.0] * S, bwd_weight_time=[2.0] * S,
        bwd_weight_saved_time=[1.0] * S,
    )

    def trace():
        return PeriodicPreemptionTrace(high=50.0, low=0.5, period=20.0, duty=0.3)

    dr = make_plan(S, M, spec=ScheduleSpec(kind="zb_h1"))
    sr = make_plan(S, M, spec=ScheduleSpec(kind="zb_h1", zb_policy="saved_residual"))
    len_dr = simulate_plan(dr, costs, uniform_network(S, trace)).pipeline_length
    len_sr = simulate_plan(sr, costs, uniform_network(S, trace)).pipeline_length

    # the tuner's per-stage policy trail (mirrors the acceptance test)
    B = 32
    mm = MemoryModel.uniform(
        num_stages=S, seq_len=64, param_bytes=1e6, optimizer_bytes=2e6,
        grad_bytes=1e6, stage_input_bytes_per_token=512.0,
        layer_act_bytes_per_token=64.0, num_layers_per_stage=2,
    )
    base = mm.peak_bytes_per_stage(make_plan(S, B, spec=ScheduleSpec(kind="zb_h1")))
    limits = [p + (1.0 if s == 0 else 1e9) for s, p in enumerate(base)]
    cands = enumerate_candidates(
        S, B, mm, limits,
        space=SearchSpace(
            kinds=("zb_h1",), max_k=1,
            zb_policies=("double_remat", "saved_residual"),
        ),
    )
    w_heavy = StageCosts(
        fwd_time=[1.0] * S, bwd_time=[4.0] * S,
        fwd_bytes=[0.01] * S, bwd_bytes=[0.01] * S,
        bwd_input_time=[1.0] * S, bwd_weight_time=[3.0] * S,
        bwd_weight_saved_time=[1.2] * S,
    )
    rec = AutoTuner(
        cands, lambda _c: w_heavy, NetworkProfiler(uniform_network(S, trace))
    ).tune(0.0)
    trail = list(rec.chosen_zb_policy)
    mixed = (
        trail
        and trail[0] == "double_remat"
        and trail[1:] == ["saved_residual"] * (S - 1)
    )
    return {
        "saved_residual_len_dr": len_dr,
        "saved_residual_len_sr": len_sr,
        "saved_residual_gain_vs_double_remat": len_dr / len_sr,
        "sr_tuner_policy_trail": trail,
        "sr_tuner_mixed_selected": int(bool(mixed)),
        "sr_tuner_chosen": rec.chosen,
    }


def saved_residual_kernel_metrics() -> dict:
    """The real-engine proof that SR's W body is genuinely cheaper: compile
    both W kernels of every stage of a tiny real model and compare their
    optimized-HLO FLOP counts.  The ratio is exactly the rematerialized
    forward double-remat pays per W task; FLOPs (not roofline seconds) are
    the honest gate — at bench-tiny shapes the residual-row read can make
    SR memory-bound even though the compiled work strictly shrinks.
    Deterministic given the model config.  Imports are local: this is part
    of the runtime suite (compiles jax programs) and ``--skip-runtime``
    must stay light."""
    import jax.numpy as jnp

    from repro.core.calibrate import calibrate_stage_costs
    from repro.models.common import ModelConfig
    from repro.pipeline.stage import StagedModel

    cfg = ModelConfig(
        name="bench-sr", family="dense", num_layers=2, d_model=32,
        num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=256,
        dtype=jnp.float32, param_dtype=jnp.float32,
    )
    staged = StagedModel.build(cfg, 2)
    cal = calibrate_stage_costs(staged, micro_batch_size=2, seq_len=8)
    ratios = [
        p["bwd_weight"].flops / p["bwd_weight_saved"].flops for p in cal.profiles
    ]
    return {
        "sr_w_flops_ratio_min": min(ratios),
        "sr_w_flops_ratios": ratios,
        "sr_w_seconds": [p["bwd_weight_saved"].seconds for p in cal.profiles],
        "dr_w_seconds": [p["bwd_weight"].seconds for p in cal.profiles],
    }


def tuner_switch_trace() -> dict:
    """Seeded Fig-10-style regime trace (4 'hours': preemption crush ->
    contended mid-bandwidth -> eased -> crush); all decisions deterministic
    given the trace seeds.

    The candidate space spans five schedule kinds at ``max_k=2`` — at
    ``max_k=4`` a single family's deepest-k member dominates every regime
    and the trajectory never leaves it (the ROADMAP-flagged degeneracy).
    With the capped space each regime has a different winner: the crush
    hours reward zero-bubble splitting (``zb_h2``), the eased hour's cheap
    links reward ZB-V's bubble-free V placement (``zbv``), and contended
    mid-bandwidth windows reward interleaving compute over the stalls
    (``interleaved``) — the per-link bursty realizations at the decision
    instants pick which of the last two regimes each non-crush hour lands
    in, and ``tuner_kind_diversity`` gates that the trajectory keeps
    crossing >= 3 kinds."""
    S, B, hour = 4, 32, 600.0
    mm = MemoryModel.uniform(
        num_stages=S, seq_len=64, param_bytes=1e6, optimizer_bytes=2e6,
        grad_bytes=1e6, stage_input_bytes_per_token=512.0,
        layer_act_bytes_per_token=64.0, num_layers_per_stage=2,
    )
    cands = enumerate_candidates(
        S, B, mm, 1e8,
        space=SearchSpace(
            kinds=("kfkb", "zb_h1", "zb_h2", "zbv", "interleaved"),
            virtual_degrees=(2,), max_k=2,
        ),
    )

    costs_by_b = {}

    def costs_for(cand):
        if cand.micro_batch_size not in costs_by_b:
            costs_by_b[cand.micro_batch_size] = StageCosts.uniform(
                S, 0.1 * cand.micro_batch_size, act_bytes=float(cand.micro_batch_size)
            )
        return costs_by_b[cand.micro_batch_size]

    def crush(seed):
        return BurstyTrace(8.0, contended_frac=0.3, mean_free=0.1,
                           mean_contended=1.0, seed=seed)

    def contended_mid(seed):
        return BurstyTrace(100.0, contended_frac=0.3, mean_free=0.1,
                           mean_contended=1.0, seed=seed)

    def link_trace(a, b):
        seed = a * 17 + b
        return RegimeTrace(
            breakpoints=[hour, 2 * hour, 3 * hour],
            traces=[crush(seed), contended_mid(seed + 7), StableTrace(200.0),
                    crush(seed + 23)],
        )

    net = Network.build(S, link_trace)
    # window == probes-per-round: each decision reads exactly the current
    # regime's samples (a wider window leaks stale-regime samples across
    # hour boundaries and blurs the regime winners)
    tuner = AutoTuner(cands, costs_for, NetworkProfiler(net, window=3))
    recs = [tuner.tune(h * hour + 30.0) for h in range(4)]
    switches = sum(1 for r in recs[1:] if r.switched)
    beat = 0
    one_f1b = next(c.name for c in cands if c.kind == "kfkb" and c.k == 1)
    for h in (0, 1, 3):  # the preempted hours
        r = recs[h]
        if r.estimates[r.chosen] < r.estimates[one_f1b]:
            beat += 1
    kinds = [r.chosen_kind for r in recs]
    return {
        "tuner_switch_count": switches,
        "tuner_chosen_kinds": kinds,
        "tuner_chosen_ks": [r.chosen_k for r in recs],
        "tuner_kind_diversity": len(set(kinds)),
        "tuner_preempted_hours_beat_1f1b": beat,
        "tuner_candidates": len(cands),
    }


def device_spec_metrics() -> dict:
    """The offline hardware-matrix slice on three committed device specs.

    Everything here is spec-derived arithmetic over the pinned workload's
    committed HLO counts — no accelerator, no wall clock — so the gates
    run at the tight deterministic band:

    * ``spec_divergent_choice`` — the synthetic extreme-skew part (memory-
      starved: every program goes memory-bound, so saved-residual's
      residual-row reads cost more than double-remat's recompute FLOPs,
      and the 6 GB capacity rejects deep warmup) must keep choosing a
      DIFFERENT ``ScheduleSpec`` than the compute-bound H100 on the same
      scenario — the acceptance proof that device data steers the tuner,
    * ``spec_h100_makespan_s`` — the chosen schedule's simulated makespan
      on H100-derived seconds (the deterministic cost-regression gate that
      replaces the old wall-clock events/sec band),
    * ``spec_slow_link_makespan_ratio`` — how much the 1 GB/s synthetic
      interconnect inflates the (re-tuned) makespan vs H100: the preempted-
      network operating point as a steady-state cost ratio.
    """
    from benchmarks.hardware_matrix import conformance_slice

    spec_dir = os.path.join(_ROOT, "specs")
    slices = {
        name: conformance_slice(os.path.join(spec_dir, f"{name}.json"))
        for name in ("h100-sxm", "synthetic-extreme-skew",
                     "synthetic-slow-interconnect")
    }
    h100 = slices["h100-sxm"]
    skew = slices["synthetic-extreme-skew"]
    slow = slices["synthetic-slow-interconnect"]
    return {
        "spec_chosen": {name: s["chosen"]["name"] for name, s in slices.items()},
        "spec_divergent_choice": int(h100["chosen"] != skew["chosen"]),
        "spec_h100_makespan_s": h100["makespan_s"]["chosen"],
        "spec_slow_link_makespan_ratio": (
            slow["makespan_s"]["chosen"] / h100["makespan_s"]["chosen"]
        ),
        "spec_h100_ratio_vs_1f1b": h100["makespan_ratio_vs_1f1b"],
    }


def simulator_throughput(repeats: int = 5) -> dict:
    """Discrete-event core speed on a fixed workload (events = executed
    tasks + completed transfers).  Wall-clock, hence gated loosely."""
    S, M, k = 8, 32, 2
    costs = StageCosts.uniform(S, 1.0, act_bytes=1.0)
    plan = make_plan(S, M, spec=ScheduleSpec(kind="zb_h1", k=k))
    net = uniform_network(S, lambda: BurstyTrace(4.0, seed=11))
    graph_tasks = sum(len(o) for o in plan.orders)
    transfers = 2 * M * (S - 1)
    events = graph_tasks + transfers
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        simulate_plan(plan, costs, net)
        best = min(best, time.perf_counter() - t0)
    return {
        "sim_events": events,
        "sim_events_per_sec": events / best,
    }


def runtime_metrics(iterations: int = 14) -> dict:
    """The live plan-switch runtime on the seeded Fig-10 scenario: real
    compiled steps (reference backend), warm kind switches across the
    interleaved re-stacking boundary, background precompilation, passive
    telemetry.  Deterministic except the wall-clock latency fractions.

    Metric definitions live in ``train_adaptive.summarize`` /
    ``grad_parity_max_err`` (shared with the entry point's JSON and the
    acceptance test); this function only renames them into the bench
    namespace.  Imports are local: this is the only benchmark that pulls
    in jax and compiles programs (~minutes), and ``--skip-runtime`` must
    stay light.
    """
    from repro.launch.train_adaptive import (
        build_fig10_scenario,
        grad_parity_max_err,
        summarize,
    )

    sc = build_fig10_scenario()
    summary = sc.coordinator.run(iterations)
    s = summarize(sc, summary)
    grad_err = grad_parity_max_err(sc)
    sc.runtime.cache.shutdown()
    return {
        "runtime_kind_switches": s["kind_switches"],
        "runtime_chosen_kinds": [d["kind"] for d in s["decision_trail"]],
        "runtime_precompile_hit_rate": s["precompile_hit_rate"],
        "runtime_cold_misses": s["cache"]["cold_misses"],
        "runtime_warm_switch_seconds": max(s["warm_switch_seconds"], default=0.0),
        "runtime_cold_switch_seconds": s["cold_switch_seconds"],
        "runtime_warm_switch_frac": s["warm_switch_latency_frac"] or 0.0,
        "runtime_mean_iteration_seconds": s["mean_iteration_seconds"],
        "runtime_probes_run": s["probe_rounds_run"],
        "runtime_probes_total": s["probe_rounds_total"],
        "runtime_probe_overhead_saved_frac": s["probe_overhead_saved_frac"],
        "runtime_grad_parity_max_err": grad_err,
        # observability (PR 9): predicted-vs-observed model health + the
        # flight-recorder decision trail (see the GATES note — both
        # deterministic: the drift join is sim-sourced on both sides)
        "model_drift_ratio": s["model_drift_ratio"],
        "model_drift_samples": s["drift_samples"],
        "tuner_decision_logged": s["tuner_decisions_logged"],
    }


def fabric_metrics(iterations: int = 8) -> dict:
    """The coordinator fabric's own health numbers on a two-host
    ``LocalTransport`` fleet (tiny 2-stage model, reference backend).

    A scripted decision trail drives the two-phase barrier through both
    verdicts, deterministically: epoch 1 proposes a spec no host can lower
    (instant fleet-wide refusal -> the aborted-switch path), epoch 2
    proposes a real candidate (precompile-vote-commit -> the warm-switch
    path, both hosts at the same boundary).  Counts and hit rates are
    deterministic; the commit's barrier latency is wall-clock (it spans
    each host's precompile), hence fingerprint-gated.  Imports are local
    for the same reason as ``runtime_metrics`` — this compiles real steps
    and ``--skip-runtime`` must stay light."""
    from repro.core import ScheduleSpec as Spec
    from repro.launch.train_adaptive import (
        build_fabric_fleet,
        fig10_parts,
        run_fabric_rounds,
    )

    _, _, cands, _ = fig10_parts(2, d_model=8)
    target = cands[1].spec

    def scripted(server):
        hist = server.barrier.history
        if not hist:
            # no host can lower this: every prepare() votes ready=False
            return Spec(kind="bogus", micro_batch_size=2)
        if len(hist) == 1:
            return target
        return None

    server, workers = build_fabric_fleet(
        num_hosts=2, num_stages=2, d_model=8, seq_len=16,
        vote_timeout=600.0, decision_fn=scripted,
    )
    try:
        out = run_fabric_rounds(server, workers, iterations)
    finally:
        for w in workers:
            w.runtime.cache.shutdown()
    fab = out["fabric"]
    commits = [r for r in server.barrier.history if r.committed]
    return {
        "fabric_hosts": fab["hosts"],
        "fabric_telemetry_windows": fab["telemetry_windows"],
        "fabric_committed_switches": fab["committed_switches"],
        "fabric_aborted_switches": fab["aborted_switches"],
        # reported only (wall-clock, not gated — see WALL_CLOCK_METRICS note)
        "fabric_barrier_latency_commit": max(
            (r.latency for r in commits), default=0.0
        ),
        # deterministic replacement gate: every committed epoch must have
        # collected a ready vote from the FULL fleet (a commit on partial
        # votes would be a barrier-protocol regression)
        "fabric_commit_ready_votes": min(
            (sum(1 for v in r.votes.values() if v.ready) for r in commits),
            default=0,
        ),
        "fabric_precompile_hit_rate_min": min(
            h["precompile_hit_rate"] for h in out["hosts"].values()
        ),
    }


def serve_metrics() -> dict:
    """Adaptive decode serving on the seeded Fig-10 serving scenario.

    Definitions live in ``repro.launch.serve_adaptive`` (shared with the
    entry point's JSON and the acceptance tests); everything runs on the
    simulated clock — arrivals, network traces, and tick pricing are all
    seeded — so every number is deterministic.  The import is local: the
    serve package pulls in the model stack, and ``--skip-runtime`` must
    stay light, but nothing here compiles a program (no engine attached).
    """
    from repro.launch.serve_adaptive import (
        chosen_specs_by_regime,
        compare_adaptive_static,
    )

    cmp = compare_adaptive_static(max_requests=60, regime="fig10", seed=0)
    div = chosen_specs_by_regime(max_requests=24, seed=0)
    a = cmp["adaptive"]
    return {
        "serve_p99_ratio_vs_static_1f1b": cmp["p99_ratio_vs_static"],
        "serve_tuner_kind_diversity": cmp["kind_diversity"],
        "serve_kinds_chosen": a["kinds_chosen"],
        "serve_slo_attainment": cmp["slo_attainment"],
        "serve_regime_divergent_choice": int(
            div["bursty"]["majority"] != div["exclusive"]["majority"]
        ),
        "serve_regime_majorities": {
            r: info["majority"] for r, info in div.items()
        },
        "serve_token_latency_p99_s": a["token_latency_p99"],
        "serve_static_token_latency_p99_s": cmp["static"]["token_latency_p99"],
        "serve_ttft_p99_s": a["ttft_p99"],
        "serve_requests_completed": a["requests_completed"],
        "serve_tokens_per_second": a["tokens_per_second"],
        "serve_validated_tracks": cmp["no_overlap_tracks"],
    }


def collect(skip_runtime: bool = False) -> dict:
    metrics = {}
    metrics.update(fig2_ratios())
    metrics.update(vector_w_gain())
    metrics.update(zbv_ratios())
    metrics.update(saved_residual_metrics())
    metrics.update(tuner_switch_trace())
    metrics.update(device_spec_metrics())
    metrics.update(simulator_throughput())
    metrics.update(serve_metrics())
    if not skip_runtime:
        metrics.update(runtime_metrics())
        metrics.update(fabric_metrics())
        metrics.update(saved_residual_kernel_metrics())
    return metrics


def previous_bench(root: str, out_name: str) -> tuple[str, dict] | None:
    """The most recent other committed BENCH_*.json (by PR number suffix)."""
    pat = re.compile(r"BENCH_PR(\d+)\.json$")
    found = []
    for f in os.listdir(root):
        m = pat.match(f)
        if m and f != out_name:
            found.append((int(m.group(1)), f))
    if not found:
        return None
    _, name = max(found)
    with open(os.path.join(root, name)) as fh:
        return name, json.load(fh)


def check_regression(metrics: dict, prev_name: str, prev: dict) -> list[str]:
    failures = []
    prev_metrics = prev.get("metrics", {})
    same_machine = prev.get("machine") == machine_fingerprint()
    for key, (direction, tol) in GATES.items():
        if key not in metrics or key not in prev_metrics:
            continue
        if key in WALL_CLOCK_METRICS and not same_machine:
            print(f"[trajectory] {key} not gated: baseline from a different "
                  f"machine ({prev_name})")
            continue
        new, old = float(metrics[key]), float(prev_metrics[key])
        if old == 0:
            continue
        if direction == "higher" and new < old * (1.0 - tol):
            failures.append(f"{key}: {new:.4g} < {old:.4g} * {1 - tol} ({prev_name})")
        if direction == "lower" and new > old * (1.0 + tol):
            failures.append(f"{key}: {new:.4g} > {old:.4g} * {1 + tol} ({prev_name})")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="write schema-versioned JSON here (e.g. BENCH_PR3.json)")
    ap.add_argument("--check", action="store_true",
                    help="fail on >10%% regression vs the previous committed BENCH_*.json")
    ap.add_argument("--skip-runtime", action="store_true",
                    help="skip the live plan-switch runtime suite (the only "
                         "one that compiles real steps; ~minutes)")
    args = ap.parse_args(argv)

    t0 = time.time()
    metrics = collect(skip_runtime=args.skip_runtime)
    payload = {
        "schema_version": SCHEMA_VERSION,
        "source": "benchmarks/trajectory.py",
        "rel_tol": REL_TOL,
        "gated": sorted(GATES),
        "machine": machine_fingerprint(),
        "metrics": metrics,
        "wall_seconds": round(time.time() - t0, 2),
    }
    print(json.dumps(payload, indent=1, default=str))
    if args.out:
        parent = os.path.dirname(os.path.abspath(args.out))
        os.makedirs(parent, exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=1, default=str)
            f.write("\n")
        print(f"[trajectory] wrote {os.path.abspath(args.out)}")
    if args.check:
        out_name = os.path.basename(args.out) if args.out else ""
        prev = previous_bench(_ROOT, out_name)
        if prev is None:
            print("[trajectory] no previous BENCH_*.json — gate passes trivially")
            return 0
        failures = check_regression(metrics, *prev)
        if failures:
            print("[trajectory] REGRESSION vs committed baseline:")
            for f in failures:
                print("  -", f)
            return 1
        print(f"[trajectory] no gated metric regressed vs {prev[0]}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
