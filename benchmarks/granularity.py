"""Paper Fig. 6 — pipeline granularity test.

8 workers, GPT-Medium, fixed global batch 192; k sweeps 1..6 with micro-
batch size 6//k (so k>1 plans pay the smaller-micro-batch efficiency
penalty, exactly as in the paper).  Five rounds probe different cluster
network states — rounds 3 and 5 are "busy" (the paper observed 1F1B
dropping to ~90% of round 1 then).  Reported numbers are relative to
1F1B @ round 1, matching the figure.

Paper claim to reproduce: k>=2 plans run 10-25% above 1F1B and stay stable
across busy rounds; gains saturate by k≈3.
"""

from __future__ import annotations

from benchmarks.common import efficiency, markdown_table, save_result
from repro.configs.gpt import GPT_CONFIGS, gpt_stage_costs
from repro.core import (
    BurstyTrace,
    make_plan,
    simulate_plan,
    uniform_network,
)

S = 8
GLOBAL_BATCH = 192
SEQ = 1024


def _costs(b: int):
    base = gpt_stage_costs(GPT_CONFIGS["GPT-Medium"], S, b, seq_len=SEQ)
    return base.scaled_to_microbatch(b, b, efficiency=None).scaled_to_microbatch(
        1, 1
    ) if False else base  # base already at micro-batch b


def costs_for(b: int):
    c = gpt_stage_costs(GPT_CONFIGS["GPT-Medium"], S, b, seq_len=SEQ)
    # apply the micro-batch efficiency penalty relative to b=6
    eff = efficiency(b) / efficiency(6)
    c.fwd_time = [t / eff for t in c.fwd_time]
    c.bwd_time = [t / eff for t in c.bwd_time]
    return c


# five rounds: (mean_free, mean_contended, contended_frac) of the bursty link
ROUNDS = {
    1: (1.0, 0.15, 0.30),
    2: (1.0, 0.20, 0.28),
    3: (0.35, 0.9, 0.12),  # busy
    4: (1.0, 0.25, 0.25),
    5: (0.30, 1.0, 0.10),  # busy
}


def run() -> dict:
    results: dict[int, dict[int, float]] = {}
    for rnd, (free, cont, frac) in ROUNDS.items():
        net = uniform_network(
            S,
            lambda free=free, cont=cont, frac=frac: BurstyTrace(
                high=25e9, contended_frac=frac,
                mean_free=free, mean_contended=cont, seed=rnd * 11,
            ),
        )
        perf = {}
        for k in range(1, 7):
            b = max(6 // k, 1)
            M = GLOBAL_BATCH // b
            plan = make_plan(S, M, k, micro_batch_size=b)
            length = simulate_plan(plan, costs_for(b), net).pipeline_length
            perf[k] = GLOBAL_BATCH / length  # samples/s
        results[rnd] = perf
    base = results[1][1]  # 1F1B @ round 1
    rows = []
    for rnd, perf in results.items():
        rows.append([f"round {rnd}"] + [f"{perf[k] / base:.3f}" for k in range(1, 7)])
    table = markdown_table(["", *(f"k={k}" for k in range(1, 7))], rows)
    print(f"\n== Fig 6: granularity, 8 stages, GB={GLOBAL_BATCH}, mbs=6//k ==")
    print(table)

    # paper claims
    rel = {r: {k: results[r][k] / base for k in range(1, 7)} for r in results}
    best_gain = max(rel[r][k] / rel[r][1] for r in rel for k in range(2, 7))
    print(f"best kFkB gain over same-round 1F1B: {(best_gain - 1) * 100:.1f}%")
    for r in (3, 5):
        assert rel[r][1] < 1.0, "busy rounds must degrade 1F1B"
        stable = max(rel[r][k] for k in range(2, 7))
        assert stable > rel[r][1], "k>1 must stay ahead in busy rounds"
    assert 1.04 <= best_gain, "expect >=4% gain somewhere (paper: 10-25%)"
    save_result("granularity", {"relative": rel, "table": table})
    return rel


if __name__ == "__main__":
    run()
